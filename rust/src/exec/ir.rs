//! The tile-program IR: a register machine over [`Tile`]s mirroring the
//! `ntl` operations the catalog application functions use (paper §3.3) —
//! load/store, zeros, dot, exp, max, sum, broadcast, element-wise
//! arithmetic — plus a single loop construct for the sub-tile sequences
//! that arrangements like mm/bmm hand to the application function.
//!
//! A [`TileProgram`] expresses the *serial* per-program semantics of the
//! paper; the grid scheduler (`super::scheduler`) runs it once per grid
//! cell, exactly as generated Triton code would be launched.

use anyhow::{anyhow, bail, Result};

use super::gemm::{gemm_rows_parallel, INTRA_PAR_MIN_MADDS};
use super::tile::{naive_dot_forced, BinOp, ReduceOp, Tile, UnaryOp};
use super::view::ParamView;
use crate::runtime::HostTensor;

pub type Reg = usize;

#[derive(Debug, Clone)]
pub enum Instr {
    /// Load the current sub-tile of a parameter into a register.
    Load { dst: Reg, param: usize },
    /// A zero tile shaped like a parameter's application block
    /// (`ntl.zeros(output.shape)`).
    Zeros { dst: Reg, like_param: usize },
    /// A scalar constant tile (shape `[1]`).
    Const { dst: Reg, value: f32 },
    Unary { dst: Reg, a: Reg, op: UnaryOp },
    Binary { dst: Reg, a: Reg, b: Reg, op: BinOp },
    /// Keep-dims reduction; `axis: None` reduces all axes.
    Reduce { dst: Reg, a: Reg, axis: Option<usize>, op: ReduceOp },
    /// 2-D matrix product.
    Dot { dst: Reg, a: Reg, b: Reg },
    /// Fused multiply-accumulate: `acc += dot(a_param, b_param)` over the
    /// current sub-tiles.  When both views lower to dense in-range
    /// windows the blocked GEMM consumes the source tensors directly (no
    /// materialized tiles); padded edge tiles fall back to gather.  This
    /// is how the mm/bmm k-loop avoids the load-materialize-dot-add
    /// round trip per iteration.
    DotAcc { acc: Reg, a_param: usize, b_param: usize },
    /// Broadcast register `a` to the block shape of a parameter.
    Broadcast { dst: Reg, a: Reg, like_param: usize },
    /// Split a tile into two equal halves along `axis` (the `x[:half]` /
    /// `x[half:]` idiom of the rope application; extent must be even).
    SplitHalf { lo: Reg, hi: Reg, a: Reg, axis: usize },
    /// Concatenate two tiles along `axis` (`ntl.cat`).
    Concat { dst: Reg, a: Reg, b: Reg, axis: usize },
    /// Iterate the body once per sub-tile (the `for k in range(...)` of
    /// the mm application).  Loops do not nest.
    Loop { body: Vec<Instr> },
    /// Store a register into the current sub-tile of a parameter.
    Store { param: usize, src: Reg },
}

#[derive(Debug, Clone)]
pub struct TileProgram {
    pub name: &'static str,
    /// number of registers the program uses
    pub regs: usize,
    pub instrs: Vec<Instr>,
}

impl TileProgram {
    /// Static sanity checks: register bounds, parameter bounds, loop
    /// nesting, stores target outputs only.
    pub fn validate(&self, n_params: usize, is_output: &[bool]) -> Result<()> {
        fn walk(
            instrs: &[Instr],
            regs: usize,
            n_params: usize,
            is_output: &[bool],
            in_loop: bool,
        ) -> Result<()> {
            for instr in instrs {
                let (rs, ps): (Vec<Reg>, Vec<usize>) = match instr {
                    Instr::Load { dst, param } => (vec![*dst], vec![*param]),
                    Instr::Zeros { dst, like_param } => (vec![*dst], vec![*like_param]),
                    Instr::Const { dst, .. } => (vec![*dst], vec![]),
                    Instr::Unary { dst, a, .. } => (vec![*dst, *a], vec![]),
                    Instr::Binary { dst, a, b, .. } => (vec![*dst, *a, *b], vec![]),
                    Instr::Reduce { dst, a, .. } => (vec![*dst, *a], vec![]),
                    Instr::Dot { dst, a, b } => (vec![*dst, *a, *b], vec![]),
                    Instr::DotAcc { acc, a_param, b_param } => {
                        (vec![*acc], vec![*a_param, *b_param])
                    }
                    Instr::Broadcast { dst, a, like_param } => {
                        (vec![*dst, *a], vec![*like_param])
                    }
                    Instr::SplitHalf { lo, hi, a, .. } => (vec![*lo, *hi, *a], vec![]),
                    Instr::Concat { dst, a, b, .. } => (vec![*dst, *a, *b], vec![]),
                    Instr::Loop { body } => {
                        if in_loop {
                            bail!("tile programs do not support nested loops");
                        }
                        walk(body, regs, n_params, is_output, true)?;
                        (vec![], vec![])
                    }
                    Instr::Store { param, src } => {
                        if !is_output.get(*param).copied().unwrap_or(false) {
                            bail!("store to non-output parameter {param}");
                        }
                        (vec![*src], vec![*param])
                    }
                };
                for r in rs {
                    if r >= regs {
                        bail!("register {r} out of range (program has {regs})");
                    }
                }
                for p in ps {
                    if p >= n_params {
                        bail!("parameter {p} out of range (program has {n_params})");
                    }
                }
            }
            Ok(())
        }
        walk(&self.instrs, self.regs, n_params, is_output, false)
    }
}

/// Where a parameter's data lives during execution.
pub enum ParamData<'a> {
    In(&'a HostTensor),
    /// Outputs are written through the scheduler's writer closure; the
    /// shape is needed for bounds/strides only (held by the view).
    Out,
}

/// Execute a tile program for one grid cell.
///
/// `write(param, flat_offset, value)` receives every in-range output
/// element the cell produces.  Distinct cells produce distinct offsets
/// (§3.2.1 non-overlap), which the scheduler relies on.
///
/// `intra_threads` is the worker budget heavy instructions (`DotAcc`)
/// may split across *within* this cell — the scheduler hands the whole
/// pool to each cell when the grid itself is too small to fill it, so a
/// big single-tile GEMM still parallelizes.
pub fn exec_cell(
    program: &TileProgram,
    views: &[ParamView],
    data: &[ParamData<'_>],
    cell: &[i64],
    loop_shape: &[usize],
    intra_threads: usize,
    write: &mut dyn FnMut(usize, usize, f32),
) -> Result<()> {
    let mut regs: Vec<Option<Tile>> = vec![None; program.regs];
    let no_sub: Vec<usize> = Vec::new();
    run_block(
        &program.instrs,
        &mut regs,
        views,
        data,
        cell,
        loop_shape,
        None,
        &no_sub,
        intra_threads,
        write,
    )
}

#[allow(clippy::too_many_arguments)]
fn run_block(
    instrs: &[Instr],
    regs: &mut Vec<Option<Tile>>,
    views: &[ParamView],
    data: &[ParamData<'_>],
    cell: &[i64],
    loop_shape: &[usize],
    sub: Option<&[usize]>,
    no_sub: &[usize],
    intra_threads: usize,
    write: &mut dyn FnMut(usize, usize, f32),
) -> Result<()> {
    // register reads borrow — every op produces a fresh output tile, so
    // no clone is needed on the hot path
    fn get(regs: &[Option<Tile>], r: Reg) -> Result<&Tile> {
        regs[r]
            .as_ref()
            .ok_or_else(|| anyhow!("read of uninitialized register {r}"))
    }
    // sub-tile coordinates for a parameter: parameters without loop levels
    // always see sub-tile 0
    fn param_sub<'a>(
        views: &[ParamView],
        param: usize,
        sub: Option<&'a [usize]>,
        no_sub: &'a [usize],
    ) -> &'a [usize] {
        if views[param].loop_shape.is_empty() {
            no_sub
        } else {
            sub.unwrap_or(no_sub)
        }
    }
    for instr in instrs {
        match instr {
            Instr::Load { dst, param } => {
                let tensor = match &data[*param] {
                    ParamData::In(t) => *t,
                    ParamData::Out => bail!("load from output parameter {param}"),
                };
                let s = param_sub(views, *param, sub, no_sub);
                if !views[*param].loop_shape.is_empty() && s.is_empty() {
                    // a looped parameter loaded outside the loop: sub-tile 0
                    let zeros = vec![0usize; views[*param].loop_shape.len()];
                    regs[*dst] = Some(views[*param].gather(tensor, cell, &zeros)?);
                } else {
                    regs[*dst] = Some(views[*param].gather(tensor, cell, s)?);
                }
            }
            Instr::Zeros { dst, like_param } => {
                regs[*dst] = Some(Tile::zeros(views[*like_param].block_shape.clone()));
            }
            Instr::Const { dst, value } => {
                regs[*dst] = Some(Tile::scalar(*value));
            }
            Instr::Unary { dst, a, op } => {
                let t = get(regs, *a)?.unary(*op);
                regs[*dst] = Some(t);
            }
            Instr::Binary { dst, a, b, op } => {
                let t = get(regs, *a)?.binary(get(regs, *b)?, *op)?;
                regs[*dst] = Some(t);
            }
            Instr::Reduce { dst, a, axis, op } => {
                let t = get(regs, *a)?.reduce(*axis, *op)?;
                regs[*dst] = Some(t);
            }
            Instr::Dot { dst, a, b } => {
                let t = get(regs, *a)?.dot(get(regs, *b)?)?;
                regs[*dst] = Some(t);
            }
            Instr::DotAcc { acc, a_param, b_param } => {
                let ta = match &data[*a_param] {
                    ParamData::In(t) => *t,
                    ParamData::Out => bail!("dot_acc reads output parameter {a_param}"),
                };
                let tb = match &data[*b_param] {
                    ParamData::In(t) => *t,
                    ParamData::Out => bail!("dot_acc reads output parameter {b_param}"),
                };
                // same "looped parameter used outside the loop sees
                // sub-tile 0" rule as Load
                let zeros_a;
                let sub_a = {
                    let v = &views[*a_param];
                    let s = param_sub(views, *a_param, sub, no_sub);
                    if !v.loop_shape.is_empty() && s.is_empty() {
                        zeros_a = vec![0usize; v.loop_shape.len()];
                        &zeros_a[..]
                    } else {
                        s
                    }
                };
                let zeros_b;
                let sub_b = {
                    let v = &views[*b_param];
                    let s = param_sub(views, *b_param, sub, no_sub);
                    if !v.loop_shape.is_empty() && s.is_empty() {
                        zeros_b = vec![0usize; v.loop_shape.len()];
                        &zeros_b[..]
                    } else {
                        s
                    }
                };
                let acc_tile = regs[*acc]
                    .as_mut()
                    .ok_or_else(|| anyhow!("read of uninitialized register {acc}"))?;
                dot_acc(
                    acc_tile,
                    &views[*a_param],
                    ta,
                    sub_a,
                    &views[*b_param],
                    tb,
                    sub_b,
                    cell,
                    intra_threads,
                )?;
            }
            Instr::Broadcast { dst, a, like_param } => {
                let t = get(regs, *a)?.broadcast_to(&views[*like_param].block_shape)?;
                regs[*dst] = Some(t);
            }
            Instr::SplitHalf { lo, hi, a, axis } => {
                let (first, second) = get(regs, *a)?.split_half(*axis)?;
                regs[*lo] = Some(first);
                regs[*hi] = Some(second);
            }
            Instr::Concat { dst, a, b, axis } => {
                let t = get(regs, *a)?.concat(get(regs, *b)?, *axis)?;
                regs[*dst] = Some(t);
            }
            Instr::Loop { body } => {
                let n: usize = loop_shape.iter().product::<usize>().max(1);
                let mut coords = vec![0usize; loop_shape.len()];
                for _ in 0..n {
                    run_block(
                        body,
                        regs,
                        views,
                        data,
                        cell,
                        loop_shape,
                        Some(&coords),
                        no_sub,
                        intra_threads,
                        write,
                    )?;
                    for d in (0..loop_shape.len()).rev() {
                        coords[d] += 1;
                        if coords[d] < loop_shape[d] {
                            break;
                        }
                        coords[d] = 0;
                    }
                }
            }
            Instr::Store { param, src } => {
                let tile = get(regs, *src)?;
                let s = param_sub(views, *param, sub, no_sub);
                views[*param].scatter_with(tile, cell, s, |off, v| write(*param, off, v))?;
            }
        }
    }
    Ok(())
}

/// `acc += A x B` for one (cell, sub) pair: direct strided reads through
/// the blocked GEMM when both views expose dense in-range windows,
/// gather fallback at padded edges (the pad value — 0 for matmul inputs
/// — contributes nothing to the product).  `intra_threads > 1` splits
/// the accumulator's rows across scoped workers when the product is big
/// enough to amortize the spawns.
#[allow(clippy::too_many_arguments)]
fn dot_acc(
    acc: &mut Tile,
    va: &ParamView,
    ta: &HostTensor,
    sub_a: &[usize],
    vb: &ParamView,
    tb: &HostTensor,
    sub_b: &[usize],
    cell: &[i64],
    intra_threads: usize,
) -> Result<()> {
    if va.block_shape.len() != 2 || vb.block_shape.len() != 2 {
        bail!(
            "dot_acc needs rank-2 blocks, got {:?} ({}) x {:?} ({})",
            va.block_shape,
            va.name,
            vb.block_shape,
            vb.name
        );
    }
    let (m, k) = (va.block_shape[0], va.block_shape[1]);
    let (kb, n) = (vb.block_shape[0], vb.block_shape[1]);
    if k != kb || acc.shape != [m, n] {
        bail!(
            "dot_acc shape mismatch: acc {:?} += {:?} ({}) x {:?} ({})",
            acc.shape,
            va.block_shape,
            va.name,
            vb.block_shape,
            vb.name
        );
    }
    if naive_dot_forced() {
        // oracle mode: the exact pre-microkernel gather + naive-dot + add
        let t = va.gather(ta, cell, sub_a)?.dot_naive(&vb.gather(tb, cell, sub_b)?)?;
        *acc = acc.binary(&t, BinOp::Add)?;
        return Ok(());
    }
    let threads = if m * n * k >= INTRA_PAR_MIN_MADDS { intra_threads.max(1) } else { 1 };
    let da = ta.as_f32()?;
    let db = tb.as_f32()?;
    match (va.dense_window(cell, sub_a), vb.dense_window(cell, sub_b)) {
        (Some((ao, asr)), Some((bo, bsr))) => {
            gemm_rows_parallel(
                threads,
                m,
                n,
                k,
                da,
                ao,
                asr[0],
                asr[1],
                db,
                bo,
                bsr[0],
                bsr[1],
                &mut acc.data,
            );
        }
        _ => {
            let tile_a = va.gather(ta, cell, sub_a)?;
            let tile_b = vb.gather(tb, cell, sub_b)?;
            gemm_rows_parallel(
                threads,
                m,
                n,
                k,
                &tile_a.data,
                0,
                k as isize,
                1,
                &tile_b.data,
                0,
                n as isize,
                1,
                &mut acc.data,
            );
        }
    }
    Ok(())
}
