//! Strided tile views: the bridge from an arrangement's *symbolic* launch
//! plan to *concrete* memory traffic.
//!
//! A [`ParamView`] is one arranged parameter specialized to concrete shape
//! and meta bindings.  Its hierarchy levels split into three classes:
//!
//! * **outermost level** — the grid (tile-to-program mapping, paper §3.2.1);
//! * **middle levels** — the loop the application function iterates
//!   (`for k in range(input.shape[0])` in the mm kernels);
//! * **innermost level** — the application tile the program computes on.
//!
//! The per-source-dim index expressions (source-to-target mapping, §3.2.2)
//! are lowered to affine form — one base plus one integer stride per level
//! variable — and *verified* against the symbolic evaluator at probe
//! points, so gather/scatter run on plain integer arithmetic (`Send +
//! Sync`, no `Rc`-based `Expr` in the hot path) without trusting the
//! lowering blindly.  Out-of-range coordinates read the parameter's pad
//! value and drop writes — the pad-and-crop edge semantics of the DSL.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::tile::Tile;
use crate::prng::SplitMix64;
use crate::runtime::HostTensor;
use crate::symbolic::Expr;
use crate::tensor::SymTensor;

/// One affine index expression: `base + Σ coeff[class][i] * var[class][i]`.
#[derive(Debug, Clone)]
struct AffineIndex {
    base: i64,
    cell: Vec<i64>,
    sub: Vec<i64>,
    inner: Vec<i64>,
}

/// One arranged parameter, specialized and lowered for native execution.
#[derive(Debug, Clone)]
pub struct ParamView {
    pub name: String,
    pub is_output: bool,
    /// concrete source-tensor shape
    pub src_shape: Vec<usize>,
    /// innermost-level (application tile) shape
    pub block_shape: Vec<usize>,
    /// flattened middle-level shape (empty = no loop)
    pub loop_shape: Vec<usize>,
    /// outermost-level shape — identical across parameters (§3.2.1)
    pub grid: Vec<i64>,
    pub pad_value: f32,
    index: Vec<AffineIndex>,
    /// row-major strides of the source tensor
    src_strides: Vec<usize>,
}

fn eval_size(size: &Expr, bindings: &BTreeMap<String, i64>, what: &str) -> Result<i64> {
    let v = size
        .substitute_consts(bindings)
        .eval(bindings)
        .with_context(|| format!("evaluating {what} size {size}"))?;
    if v < 0 {
        bail!("{what} size {size} evaluated to negative {v}");
    }
    Ok(v)
}

impl ParamView {
    /// Lower one arranged parameter under concrete bindings.
    ///
    /// `src_shape` is the concrete source-tensor shape; `bindings` must
    /// cover every size/meta symbol the arrangement references.
    pub fn specialize(
        tensor: &SymTensor,
        bindings: &BTreeMap<String, i64>,
        src_shape: &[usize],
        is_output: bool,
        pad_value: f32,
    ) -> Result<ParamView> {
        let name = tensor.name.clone();
        if tensor.levels.len() < 2 {
            bail!("parameter {name}: arrangement needs at least outer + tile levels");
        }
        if tensor.indices.len() != src_shape.len() {
            bail!(
                "parameter {name}: {} index expressions for source rank {}",
                tensor.indices.len(),
                src_shape.len()
            );
        }
        tensor.validate_checks(bindings)?;

        // level sizes + variable classification
        let n_levels = tensor.levels.len();
        let mut grid = Vec::new();
        let mut loop_shape = Vec::new();
        let mut block_shape = Vec::new();
        // (var name, class, position): class 0 = cell, 1 = sub, 2 = inner
        let mut vars: Vec<(String, usize, usize)> = Vec::new();
        for (li, level) in tensor.levels.iter().enumerate() {
            let class = if li == 0 {
                0
            } else if li + 1 == n_levels {
                2
            } else {
                1
            };
            for dim in level {
                let size = eval_size(&dim.size, bindings, &format!("parameter {name} level {li}"))?;
                let pos = match class {
                    0 => {
                        grid.push(size);
                        grid.len() - 1
                    }
                    1 => {
                        loop_shape.push(size as usize);
                        loop_shape.len() - 1
                    }
                    _ => {
                        block_shape.push(size as usize);
                        block_shape.len() - 1
                    }
                };
                vars.push((dim.var.clone(), class, pos));
            }
        }
        // drop size-1 middle dims: they carry no loop structure
        // (keep coefficients aligned by NOT dropping — a size-1 loop dim
        //  simply never advances, which is equivalent and simpler)

        // affine lowering of each index expression
        let zero_env = |env: &mut BTreeMap<String, i64>| {
            for (v, _, _) in &vars {
                env.insert(v.clone(), 0);
            }
        };
        let mut index = Vec::new();
        for expr in &tensor.indices {
            let spec = expr.substitute_consts(bindings);
            let mut env = bindings.clone();
            zero_env(&mut env);
            let base = spec
                .eval(&env)
                .with_context(|| format!("parameter {name}: index {expr} at origin"))?;
            let mut aff = AffineIndex {
                base,
                cell: vec![0; grid.len()],
                sub: vec![0; loop_shape.len()],
                inner: vec![0; block_shape.len()],
            };
            for (v, class, pos) in &vars {
                env.insert(v.clone(), 1);
                let coeff = spec
                    .eval(&env)
                    .with_context(|| format!("parameter {name}: index {expr} probing {v}"))?
                    - base;
                env.insert(v.clone(), 0);
                match *class {
                    0 => aff.cell[*pos] += coeff,
                    1 => aff.sub[*pos] += coeff,
                    _ => aff.inner[*pos] += coeff,
                }
            }
            // verify the lowering is exact (the expression is affine) at
            // deterministic probe points: all-max plus pseudo-random
            let var_max = |class: usize, pos: usize| -> i64 {
                match class {
                    0 => (grid[pos] - 1).max(0),
                    1 => (loop_shape[pos] as i64 - 1).max(0),
                    _ => (block_shape[pos] as i64 - 1).max(0),
                }
            };
            let mut rng = SplitMix64::new(0x9e37 ^ base as u64);
            for probe in 0..4 {
                let mut env = bindings.clone();
                let mut predicted = base;
                for (v, class, pos) in &vars {
                    let hi = var_max(*class, *pos);
                    let val = if probe == 0 { hi } else { rng.below(hi as u64 + 1) as i64 };
                    env.insert(v.clone(), val);
                    let coeff = match *class {
                        0 => aff.cell[*pos],
                        1 => aff.sub[*pos],
                        _ => aff.inner[*pos],
                    };
                    predicted += coeff * val;
                }
                let actual = spec
                    .eval(&env)
                    .with_context(|| format!("parameter {name}: index {expr} probe"))?;
                if actual != predicted {
                    bail!(
                        "parameter {name}: index expression {expr} is not affine in its \
                         level variables (probe disagrees: {actual} vs {predicted}); \
                         the native backend cannot lower this arrangement"
                    );
                }
            }
            index.push(aff);
        }

        let mut src_strides = vec![0usize; src_shape.len()];
        let mut acc = 1usize;
        for (dim, stride) in src_shape.iter().zip(src_strides.iter_mut()).rev() {
            *stride = acc;
            acc *= dim;
        }

        Ok(ParamView {
            name,
            is_output,
            src_shape: src_shape.to_vec(),
            block_shape,
            loop_shape,
            grid,
            pad_value,
            index,
            src_strides,
        })
    }

    /// Number of loop iterations (sub-tiles) one grid cell sees.
    pub fn n_sub(&self) -> usize {
        self.loop_shape.iter().product::<usize>().max(1)
    }

    /// True if adjacent cells along grid dimension `g` provably address
    /// disjoint source regions: some source dim's cell stride along `g`
    /// is at least the full span that dim's coordinates cover within one
    /// cell (over all inner and loop variables).  The scheduler requires
    /// this of every output view on every non-trivial grid dim before
    /// parallelizing — two cells writing the same offsets concurrently
    /// would be a data race.
    pub fn grid_dim_disjoint(&self, g: usize) -> bool {
        self.index.iter().any(|aff| {
            let stride = aff.cell.get(g).copied().unwrap_or(0).abs();
            if stride == 0 {
                return false;
            }
            // widest window this source dim's coordinate sweeps per cell
            let mut span: i64 = 1;
            for (coeff, dim) in aff.inner.iter().zip(&self.block_shape) {
                span += coeff.abs() * (*dim as i64 - 1).max(0);
            }
            for (coeff, dim) in aff.sub.iter().zip(&self.loop_shape) {
                span += coeff.abs() * (*dim as i64 - 1).max(0);
            }
            stride >= span
        })
    }

    /// Affine profile of source dimension `d` for structural analyses
    /// (`kernel::make`'s row-independence derivation): the per-grid-axis
    /// cell coefficients plus the widest spans the loop and block
    /// variables sweep along that dim within one program instance.
    pub(crate) fn dim_profile(&self, d: usize) -> (Vec<i64>, i64, i64) {
        let aff = &self.index[d];
        let sub_span: i64 = aff
            .sub
            .iter()
            .zip(&self.loop_shape)
            .map(|(coeff, &dim)| coeff.abs() * (dim as i64 - 1).max(0))
            .sum();
        let inner_span: i64 = aff
            .inner
            .iter()
            .zip(&self.block_shape)
            .map(|(coeff, &dim)| coeff.abs() * (dim as i64 - 1).max(0))
            .sum();
        (aff.cell.clone(), sub_span, inner_span)
    }

    /// If the whole block at (cell, sub) maps to in-range source elements
    /// — no pad reads, no dropped writes — return its flat base offset
    /// plus one flat stride per block dimension.  The affine lowering
    /// makes every element's flat offset `base + Σ block_coord[b] *
    /// stride[b]`, so consumers (the fused `DotAcc` GEMM) can read the
    /// source buffer directly instead of materializing a tile.  `None`
    /// means some coordinate pads: callers fall back to `gather`.
    pub fn dense_window(&self, cell: &[i64], sub: &[usize]) -> Option<(usize, Vec<isize>)> {
        let starts = self.starts(cell, sub);
        let mut base: i64 = 0;
        for (d, (&start, aff)) in starts.iter().zip(&self.index).enumerate() {
            // extreme coordinates this source dim reaches over the block
            let (mut lo, mut hi) = (start, start);
            for (&coeff, &dim) in aff.inner.iter().zip(&self.block_shape) {
                let extent = coeff * (dim as i64 - 1).max(0);
                if extent >= 0 {
                    hi += extent;
                } else {
                    lo += extent;
                }
            }
            if lo < 0 || hi >= self.src_shape[d] as i64 {
                return None;
            }
            base += start * self.src_strides[d] as i64;
        }
        let flat = (0..self.block_shape.len())
            .map(|b| {
                self.index
                    .iter()
                    .zip(&self.src_strides)
                    .map(|(aff, &stride)| aff.inner[b] as isize * stride as isize)
                    .sum()
            })
            .collect();
        Some((base as usize, flat))
    }

    /// Per-source-dim start coordinate for a (cell, sub) pair.
    fn starts(&self, cell: &[i64], sub: &[usize]) -> Vec<i64> {
        self.index
            .iter()
            .map(|aff| {
                let mut v = aff.base;
                for (c, coeff) in cell.iter().zip(&aff.cell) {
                    v += c * coeff;
                }
                for (s, coeff) in sub.iter().zip(&aff.sub) {
                    v += *s as i64 * coeff;
                }
                v
            })
            .collect()
    }

    /// Walk every element of the block at (cell, sub), yielding
    /// `(flat source offset or None-if-padded)` in row-major block order.
    fn for_each_coord<F: FnMut(Option<usize>)>(&self, cell: &[i64], sub: &[usize], mut f: F) {
        let starts = self.starts(cell, sub);
        let rank = self.src_shape.len();
        let n: usize = self.block_shape.iter().product::<usize>().max(1);
        let mut block_coords = vec![0usize; self.block_shape.len()];
        // coords[d] for the current element, updated incrementally
        let mut coords = starts.clone();
        for _ in 0..n {
            let mut off = 0usize;
            let mut inside = true;
            for d in 0..rank {
                let c = coords[d];
                if c < 0 || c >= self.src_shape[d] as i64 {
                    inside = false;
                    break;
                }
                off += c as usize * self.src_strides[d];
            }
            f(if inside { Some(off) } else { None });
            // odometer over block coords; coords[d] updated by the
            // per-inner-variable stride of each source dim
            for b in (0..self.block_shape.len()).rev() {
                block_coords[b] += 1;
                for (d, aff) in self.index.iter().enumerate() {
                    coords[d] += aff.inner[b];
                }
                if block_coords[b] < self.block_shape[b] {
                    break;
                }
                for (d, aff) in self.index.iter().enumerate() {
                    coords[d] -= aff.inner[b] * self.block_shape[b] as i64;
                }
                block_coords[b] = 0;
            }
        }
    }

    /// Materialize the block at (cell, sub) from a source tensor,
    /// padding out-of-range elements.
    pub fn gather(&self, src: &HostTensor, cell: &[i64], sub: &[usize]) -> Result<Tile> {
        let data = src.as_f32()?;
        let n: usize = self.block_shape.iter().product::<usize>().max(1);
        let mut out = Vec::with_capacity(n);
        self.for_each_coord(cell, sub, |off| {
            out.push(match off {
                Some(o) => data[o],
                None => self.pad_value,
            });
        });
        Tile::new(self.block_shape.clone(), out)
    }

    /// The padding mask of the block at (cell, sub): a block-shaped tile
    /// holding `0.0` where the source coordinate is in range and `value`
    /// where a gather would read the pad value.  Applications add it
    /// (with a large negative `value`) to attention scores so padded key
    /// rows can never win an online softmax — the data-free analogue of
    /// the `mask ? score : -inf` select in hand-written Triton kernels.
    pub fn pad_mask(&self, cell: &[i64], sub: &[usize], value: f32) -> Tile {
        let n: usize = self.block_shape.iter().product::<usize>().max(1);
        let mut out = Vec::with_capacity(n);
        self.for_each_coord(cell, sub, |off| {
            out.push(if off.is_some() { 0.0 } else { value });
        });
        Tile { shape: self.block_shape.clone(), data: out }
    }

    /// Scatter a computed block back, dropping out-of-range elements.
    /// `write(flat_offset, value)` receives only in-range destinations —
    /// the §3.2.1 non-overlap property guarantees distinct grid cells hit
    /// distinct offsets, which is what makes the grid parallelizable.
    pub fn scatter_with<F: FnMut(usize, f32)>(
        &self,
        tile: &Tile,
        cell: &[i64],
        sub: &[usize],
        mut write: F,
    ) -> Result<()> {
        if tile.shape != self.block_shape {
            bail!(
                "store of tile shape {:?} into parameter {} with block {:?}",
                tile.shape,
                self.name,
                self.block_shape
            );
        }
        let mut it = tile.data.iter();
        self.for_each_coord(cell, sub, |off| {
            let v = *it.next().expect("tile length matches block");
            if let Some(o) = off {
                write(o, v);
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SymTensor;

    fn bind(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn view_1d(n: usize, block: i64) -> ParamView {
        let t = SymTensor::new("x", 1)
            .tile(&[Some(Expr::sym("B"))], None)
            .unwrap();
        let bindings = bind(&[("x_size_0", n as i64), ("B", block)]);
        ParamView::specialize(&t, &bindings, &[n], false, -1.0).unwrap()
    }

    #[test]
    fn gather_pads_the_tail() {
        let view = view_1d(10, 4);
        assert_eq!(view.grid, vec![3]);
        assert_eq!(view.block_shape, vec![4]);
        let src = HostTensor::f32(vec![10], (0..10).map(|i| i as f32).collect()).unwrap();
        let t = view.gather(&src, &[2], &[]).unwrap();
        assert_eq!(t.data, vec![8.0, 9.0, -1.0, -1.0]);
    }

    #[test]
    fn scatter_drops_the_tail() {
        let view = view_1d(10, 4);
        let tile = Tile::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let mut writes = Vec::new();
        view.scatter_with(&tile, &[2], &[], |off, v| writes.push((off, v))).unwrap();
        assert_eq!(writes, vec![(8, 1.0), (9, 2.0)]);
    }

    #[test]
    fn dense_window_matches_gather_and_detects_padding() {
        // 10 elements tiled by 4: cells 0/1 are dense, cell 2 pads
        let view = view_1d(10, 4);
        let src = HostTensor::f32(vec![10], (0..10).map(|i| i as f32).collect()).unwrap();
        for cell in [0i64, 1] {
            let (off, strides) = view.dense_window(&[cell], &[]).expect("interior cell is dense");
            assert_eq!(strides, vec![1]);
            let tile = view.gather(&src, &[cell], &[]).unwrap();
            let data = src.as_f32().unwrap();
            for (i, &v) in tile.data.iter().enumerate() {
                assert_eq!(data[(off as isize + i as isize * strides[0]) as usize], v);
            }
        }
        assert!(view.dense_window(&[2], &[]).is_none(), "padded tail must not be dense");
    }

    #[test]
    fn pad_mask_marks_exactly_the_padded_lanes() {
        // 10 elements tiled by 4: cell 1 is interior, cell 2 pads 2 lanes
        let view = view_1d(10, 4);
        let interior = view.pad_mask(&[1], &[], -1e30);
        assert_eq!(interior.shape, vec![4]);
        assert_eq!(interior.data, vec![0.0; 4]);
        let tail = view.pad_mask(&[2], &[], -1e30);
        assert_eq!(tail.data, vec![0.0, 0.0, -1e30, -1e30]);
    }

    #[test]
    fn dense_window_reports_non_unit_strides() {
        // [4, 6] matrix tiled into [2, 3] blocks: block dim 0 walks the
        // source with stride 6 (a non-contiguous window of the flat buffer)
        let t = SymTensor::new("x", 2)
            .tile(&[Some(Expr::Const(2)), Some(Expr::Const(3))], None)
            .unwrap();
        let bindings = bind(&[("x_size_0", 4), ("x_size_1", 6)]);
        let view = ParamView::specialize(&t, &bindings, &[4, 6], false, 0.0).unwrap();
        let (off, strides) = view.dense_window(&[1, 1], &[]).unwrap();
        assert_eq!(off, 2 * 6 + 3);
        assert_eq!(strides, vec![6, 1]);
    }

    #[test]
    fn mm_input_view_walks_k_tiles() {
        // the Listing-5 input arrangement: [M, K] seen as (gm, gn) grid of
        // k-sequences of [BM, BK] tiles
        let tensors = crate::arrange::catalog::mm().unwrap();
        let input = &tensors[0];
        let bindings = bind(&[
            ("BLOCK_SIZE_M", 2),
            ("BLOCK_SIZE_N", 2),
            ("BLOCK_SIZE_K", 2),
            ("input_size_0", 4),
            ("input_size_1", 4),
            ("other_size_0", 4),
            ("other_size_1", 4),
            ("output_size_0", 4),
            ("output_size_1", 4),
        ]);
        let view = ParamView::specialize(input, &bindings, &[4, 4], false, 0.0).unwrap();
        assert_eq!(view.grid, vec![2, 2]);
        assert_eq!(view.loop_shape, vec![2]);
        assert_eq!(view.block_shape, vec![2, 2]);
        let src =
            HostTensor::f32(vec![4, 4], (0..16).map(|i| i as f32).collect()).unwrap();
        // cell (1, 0) [second row-block], k-tile 1 → rows 2..4, cols 2..4
        let t = view.gather(&src, &[1, 0], &[1]).unwrap();
        assert_eq!(t.data, vec![10.0, 11.0, 14.0, 15.0]);
        // the expanded grid dim must not move the input view
        let t2 = view.gather(&src, &[1, 1], &[1]).unwrap();
        assert_eq!(t2.data, t.data);
    }
}
