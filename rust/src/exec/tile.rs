//! Dense f32 tiles: the value type the tile-program interpreter computes
//! on.  A tile is the materialized innermost level of one arranged
//! parameter at one grid cell — small (a block), row-major, always f32
//! (the accumulation dtype of every catalog application function).
//!
//! Binary operations broadcast with NumPy right-alignment semantics, which
//! is exactly what `ntl` expressions like `x - max(x)` need after a
//! keep-dim reduction.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use anyhow::{bail, Result};

use super::gemm;

/// Programmatic override for [`naive_dot_forced`] — lets tests exercise
/// the oracle path without touching the process environment (env writes
/// race with concurrent `getenv` on glibc, which is why `set_var` is
/// unsafe in newer editions).
static FORCE_NAIVE: AtomicBool = AtomicBool::new(false);

/// Force (or stop forcing) the naive dot path from code.
pub fn set_naive_dot_forced(forced: bool) {
    FORCE_NAIVE.store(forced, Ordering::Relaxed);
}

/// True when `NT_NAIVE_DOT=1` (read once) or [`set_naive_dot_forced`]
/// is active: every `dot` — including the fused `DotAcc` — takes the
/// naive gather + i-k-j path.  The flag keeps the pre-microkernel path
/// alive as the correctness oracle for property tests and as the
/// baseline the bench gate measures the blocked kernel against.
pub fn naive_dot_forced() -> bool {
    static FORCED: OnceLock<bool> = OnceLock::new();
    *FORCED.get_or_init(|| std::env::var("NT_NAIVE_DOT").is_ok_and(|v| v == "1"))
        || FORCE_NAIVE.load(Ordering::Relaxed)
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tile {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnaryOp {
    Exp,
    Sigmoid,
    Rsqrt,
    Neg,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
    Mean,
}

fn elem_count(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Row-major strides for a shape.
fn strides(shape: &[usize]) -> Vec<usize> {
    let mut out = vec![0; shape.len()];
    let mut acc = 1;
    for (dim, stride) in shape.iter().zip(out.iter_mut()).rev() {
        *stride = acc;
        acc *= dim;
    }
    out
}

impl Tile {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tile> {
        if data.len() != elem_count(&shape) {
            bail!("tile shape {shape:?} needs {} elements, got {}", elem_count(&shape), data.len());
        }
        Ok(Tile { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tile {
        let n = elem_count(&shape);
        Tile { shape, data: vec![0.0; n] }
    }

    pub fn scalar(value: f32) -> Tile {
        Tile { shape: vec![1], data: vec![value] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn unary(&self, op: UnaryOp) -> Tile {
        let f: fn(f32) -> f32 = match op {
            UnaryOp::Exp => f32::exp,
            UnaryOp::Sigmoid => |x: f32| 1.0 / (1.0 + (-x).exp()),
            UnaryOp::Rsqrt => |x: f32| 1.0 / x.sqrt(),
            UnaryOp::Neg => |x: f32| -x,
        };
        Tile { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Broadcasted result shape of two operands (NumPy right-alignment).
    fn broadcast_shape(a: &[usize], b: &[usize]) -> Result<Vec<usize>> {
        let rank = a.len().max(b.len());
        let mut out = vec![0; rank];
        for i in 0..rank {
            let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
            let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
            out[i] = match (da, db) {
                (x, y) if x == y => x,
                (1, y) => y,
                (x, 1) => x,
                (x, y) => bail!("cannot broadcast {a:?} with {b:?} (dim {x} vs {y})"),
            };
        }
        Ok(out)
    }

    /// Strides of an operand viewed at the broadcast rank (0 on expanded
    /// or size-1 dims).
    fn broadcast_strides(shape: &[usize], out: &[usize]) -> Vec<usize> {
        let own = strides(shape);
        let offset = out.len() - shape.len();
        (0..out.len())
            .map(|i| {
                if i < offset || shape[i - offset] == 1 {
                    0
                } else {
                    own[i - offset]
                }
            })
            .collect()
    }

    pub fn binary(&self, other: &Tile, op: BinOp) -> Result<Tile> {
        let f: fn(f32, f32) -> f32 = match op {
            BinOp::Add => |x: f32, y: f32| x + y,
            BinOp::Sub => |x: f32, y: f32| x - y,
            BinOp::Mul => |x: f32, y: f32| x * y,
            BinOp::Div => |x: f32, y: f32| x / y,
            BinOp::Max => f32::max,
        };
        let shape = Tile::broadcast_shape(&self.shape, &other.shape)?;
        if shape == self.shape && shape == other.shape {
            // fast path: identical shapes
            let data = self.data.iter().zip(&other.data).map(|(&x, &y)| f(x, y)).collect();
            return Ok(Tile { shape, data });
        }
        let sa = Tile::broadcast_strides(&self.shape, &shape);
        let sb = Tile::broadcast_strides(&other.shape, &shape);
        let n = elem_count(&shape);
        let mut data = Vec::with_capacity(n);
        let mut coords = vec![0usize; shape.len()];
        let (mut ia, mut ib) = (0usize, 0usize);
        for _ in 0..n {
            data.push(f(self.data[ia], other.data[ib]));
            // odometer increment with incremental flat offsets
            for d in (0..shape.len()).rev() {
                coords[d] += 1;
                ia += sa[d];
                ib += sb[d];
                if coords[d] < shape[d] {
                    break;
                }
                ia -= sa[d] * shape[d];
                ib -= sb[d] * shape[d];
                coords[d] = 0;
            }
        }
        Ok(Tile { shape, data })
    }

    /// Reduce with keep-dims: `axis: None` reduces every axis (result is
    /// all-ones shape of the same rank), `Some(d)` reduces only axis `d`.
    pub fn reduce(&self, axis: Option<usize>, op: ReduceOp) -> Result<Tile> {
        let rank = self.shape.len();
        if let Some(d) = axis {
            if d >= rank {
                bail!("reduce axis {d} out of range for shape {:?}", self.shape);
            }
        }
        let reduced: Vec<bool> = (0..rank).map(|d| axis.map(|a| a == d).unwrap_or(true)).collect();
        let out_shape: Vec<usize> = self
            .shape
            .iter()
            .zip(&reduced)
            .map(|(&s, &r)| if r { 1 } else { s })
            .collect();
        let count: usize = self
            .shape
            .iter()
            .zip(&reduced)
            .filter(|(_, &r)| r)
            .map(|(&s, _)| s)
            .product();
        if count == 0 {
            bail!("reduce over zero elements in shape {:?}", self.shape);
        }
        let out_strides = strides(&out_shape);
        let n_out = elem_count(&out_shape);
        let init = match op {
            ReduceOp::Sum | ReduceOp::Mean => 0.0f64,
            ReduceOp::Max => f64::NEG_INFINITY,
        };
        let mut acc = vec![init; n_out];
        let mut coords = vec![0usize; rank];
        for &v in &self.data {
            let mut off = 0;
            for d in 0..rank {
                if !reduced[d] {
                    off += coords[d] * out_strides[d];
                }
            }
            match op {
                ReduceOp::Sum | ReduceOp::Mean => acc[off] += v as f64,
                ReduceOp::Max => acc[off] = acc[off].max(v as f64),
            }
            for d in (0..rank).rev() {
                coords[d] += 1;
                if coords[d] < self.shape[d] {
                    break;
                }
                coords[d] = 0;
            }
        }
        let scale = if op == ReduceOp::Mean { 1.0 / count as f64 } else { 1.0 };
        Ok(Tile {
            shape: out_shape,
            data: acc.into_iter().map(|v| (v * scale) as f32).collect(),
        })
    }

    /// Split the tile into two equal halves along `axis` (the `x[:half]` /
    /// `x[half:]` idiom of rotary-embedding application functions).  The
    /// axis extent must be even.
    pub fn split_half(&self, axis: usize) -> Result<(Tile, Tile)> {
        let rank = self.shape.len();
        if axis >= rank {
            bail!("split_half axis {axis} out of range for shape {:?}", self.shape);
        }
        let len = self.shape[axis];
        if len == 0 || len % 2 != 0 {
            bail!("split_half needs an even extent along axis {axis}, got {:?}", self.shape);
        }
        let half = len / 2;
        let inner: usize = self.shape[axis + 1..].iter().product();
        let outer: usize = self.shape[..axis].iter().product();
        let mut shape = self.shape.clone();
        shape[axis] = half;
        let mut lo = Vec::with_capacity(outer * half * inner);
        let mut hi = Vec::with_capacity(outer * half * inner);
        for o in 0..outer {
            let base = o * len * inner;
            lo.extend_from_slice(&self.data[base..base + half * inner]);
            hi.extend_from_slice(&self.data[base + half * inner..base + len * inner]);
        }
        Ok((Tile { shape: shape.clone(), data: lo }, Tile { shape, data: hi }))
    }

    /// Concatenate two tiles along `axis` (the `ntl.cat` of the rope
    /// application); all other extents must agree.
    pub fn concat(&self, other: &Tile, axis: usize) -> Result<Tile> {
        let rank = self.shape.len();
        if other.shape.len() != rank || axis >= rank {
            bail!(
                "concat along axis {axis} needs equal-rank tiles, got {:?} and {:?}",
                self.shape,
                other.shape
            );
        }
        for d in 0..rank {
            if d != axis && self.shape[d] != other.shape[d] {
                bail!(
                    "concat along axis {axis}: extents disagree off-axis ({:?} vs {:?})",
                    self.shape,
                    other.shape
                );
            }
        }
        let inner: usize = self.shape[axis + 1..].iter().product();
        let outer: usize = self.shape[..axis].iter().product();
        let (la, lb) = (self.shape[axis], other.shape[axis]);
        let mut shape = self.shape.clone();
        shape[axis] = la + lb;
        let mut data = Vec::with_capacity(self.data.len() + other.data.len());
        for o in 0..outer {
            data.extend_from_slice(&self.data[o * la * inner..(o + 1) * la * inner]);
            data.extend_from_slice(&other.data[o * lb * inner..(o + 1) * lb * inner]);
        }
        Ok(Tile { shape, data })
    }

    /// Validated `[M, K] x [K, N]` dimensions for a matrix product.
    /// Rank and inner-dimension problems are reported here so every dot
    /// variant fails with the same clean error instead of relying on
    /// caller invariants.
    fn dot_dims(&self, other: &Tile) -> Result<(usize, usize, usize)> {
        let (a, b) = (self, other);
        if a.shape.len() != 2 || b.shape.len() != 2 {
            bail!(
                "dot expects two rank-2 tiles, got rank {} ({:?}) x rank {} ({:?})",
                a.shape.len(),
                a.shape,
                b.shape.len(),
                b.shape
            );
        }
        if a.shape[1] != b.shape[0] {
            bail!(
                "dot inner-dimension mismatch: {:?} x {:?} (k = {} vs {})",
                a.shape,
                b.shape,
                a.shape[1],
                b.shape[0]
            );
        }
        Ok((a.shape[0], a.shape[1], b.shape[1]))
    }

    /// 2-D matrix product `[M, K] x [K, N] -> [M, N]` (f32 accumulate).
    /// Routes to the blocked microkernel ([`gemm`]) unless
    /// `NT_NAIVE_DOT=1` forces the legacy naive loop.
    pub fn dot(&self, other: &Tile) -> Result<Tile> {
        if naive_dot_forced() {
            self.dot_naive(other)
        } else {
            self.dot_blocked(other)
        }
    }

    /// The blocked, cache-aware matrix product (packed panels + MR x NR
    /// register tile; see [`gemm`]).
    pub fn dot_blocked(&self, other: &Tile) -> Result<Tile> {
        let (m, k, n) = self.dot_dims(other)?;
        let mut out = vec![0.0f32; m * n];
        gemm::gemm(
            m,
            n,
            k,
            &self.data,
            0,
            k as isize,
            1,
            &other.data,
            0,
            n as isize,
            1,
            &mut out,
            0,
            n,
        );
        Ok(Tile { shape: vec![m, n], data: out })
    }

    /// The original naive i-k-j loop — the innermost loop walks both `b`
    /// and `out` rows contiguously.  Kept as the correctness oracle the
    /// blocked path is property-tested against, and as the baseline the
    /// bench gate measures.
    pub fn dot_naive(&self, other: &Tile) -> Result<Tile> {
        let (m, k, n) = self.dot_dims(other)?;
        let (a, b) = (self, other);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                let brow = &b.data[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        Ok(Tile { shape: vec![m, n], data: out })
    }

    /// Broadcast this tile to the shape of `like` (via `+ zeros(like)`).
    pub fn broadcast_to(&self, like: &[usize]) -> Result<Tile> {
        self.binary(&Tile::zeros(like.to_vec()), BinOp::Add)
    }

    /// 2-D matrix transpose (`ntl.trans`): `[M, N] -> [N, M]`.  The
    /// flash-attention application transposes the key block before the
    /// `dot(q, trans(k))` score product.
    pub fn transpose(&self) -> Result<Tile> {
        if self.shape.len() != 2 {
            bail!("transpose expects a rank-2 tile, got {:?}", self.shape);
        }
        let (rows, cols) = (self.shape[0], self.shape[1]);
        let mut data = vec![0.0f32; rows * cols];
        for i in 0..rows {
            for (j, &v) in self.data[i * cols..(i + 1) * cols].iter().enumerate() {
                data[j * rows + i] = v;
            }
        }
        Ok(Tile { shape: vec![cols, rows], data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_broadcasts_rowwise() {
        let x = Tile::new(vec![1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let m = Tile::new(vec![1, 1], vec![4.0]).unwrap();
        let d = x.binary(&m, BinOp::Sub).unwrap();
        assert_eq!(d.shape, vec![1, 4]);
        assert_eq!(d.data, vec![-3.0, -2.0, -1.0, 0.0]);
    }

    #[test]
    fn binary_broadcasts_rank_mismatch() {
        let x = Tile::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let s = Tile::scalar(10.0);
        let y = x.binary(&s, BinOp::Add).unwrap();
        assert_eq!(y.shape, vec![2, 2]);
        assert_eq!(y.data, vec![11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn reduce_axis_and_all() {
        let x = Tile::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let rows = x.reduce(Some(1), ReduceOp::Sum).unwrap();
        assert_eq!(rows.shape, vec![2, 1]);
        assert_eq!(rows.data, vec![6.0, 15.0]);
        let all = x.reduce(None, ReduceOp::Max).unwrap();
        assert_eq!(all.shape, vec![1, 1]);
        assert_eq!(all.data, vec![6.0]);
        let mean = x.reduce(None, ReduceOp::Mean).unwrap();
        assert_eq!(mean.data, vec![3.5]);
    }

    #[test]
    fn dot_matches_by_hand() {
        let a = Tile::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tile::new(vec![2, 2], vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        let c = a.dot(&b).unwrap();
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn bad_broadcast_rejected() {
        let a = Tile::zeros(vec![2, 3]);
        let b = Tile::zeros(vec![2, 4]);
        assert!(a.binary(&b, BinOp::Add).is_err());
    }

    #[test]
    fn dot_rejects_non_rank2_operands() {
        let vec1 = Tile::zeros(vec![4]);
        let mat = Tile::zeros(vec![4, 4]);
        let cube = Tile::zeros(vec![2, 2, 2]);
        for (a, b) in [(&vec1, &mat), (&mat, &vec1), (&cube, &mat), (&mat, &cube)] {
            for result in [a.dot(b), a.dot_naive(b), a.dot_blocked(b)] {
                let msg = format!("{:#}", result.unwrap_err());
                assert!(msg.contains("rank-2"), "unexpected error: {msg}");
            }
        }
    }

    #[test]
    fn split_half_and_concat_roundtrip() {
        let t = Tile::new(vec![2, 4], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]).unwrap();
        let (lo, hi) = t.split_half(1).unwrap();
        assert_eq!(lo.shape, vec![2, 2]);
        assert_eq!(lo.data, vec![0.0, 1.0, 4.0, 5.0]);
        assert_eq!(hi.data, vec![2.0, 3.0, 6.0, 7.0]);
        assert_eq!(lo.concat(&hi, 1).unwrap(), t);
        let (top, bottom) = t.split_half(0).unwrap();
        assert_eq!(top.shape, vec![1, 4]);
        assert_eq!(top.concat(&bottom, 0).unwrap(), t);
        // odd extents and bad axes are clean errors
        assert!(Tile::zeros(vec![3]).split_half(0).is_err());
        assert!(t.split_half(2).is_err());
        assert!(lo.concat(&Tile::zeros(vec![3, 2]), 1).is_err());
    }

    #[test]
    fn transpose_roundtrips_and_rejects_bad_ranks() {
        let t = Tile::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let tt = t.transpose().unwrap();
        assert_eq!(tt.shape, vec![3, 2]);
        assert_eq!(tt.data, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(tt.transpose().unwrap(), t);
        // row/column vectors stay rank-2
        let row = Tile::new(vec![1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(row.transpose().unwrap().shape, vec![4, 1]);
        for bad in [Tile::zeros(vec![4]), Tile::zeros(vec![2, 2, 2])] {
            let msg = format!("{:#}", bad.transpose().unwrap_err());
            assert!(msg.contains("rank-2"), "unexpected error: {msg}");
        }
    }

    #[test]
    fn split_half_and_concat_reject_bad_inputs_cleanly() {
        // regression sweep: axis-out-of-range, odd/zero extents, rank and
        // off-axis mismatches are all Err — never a panic or slice OOB
        let t = Tile::new(vec![2, 4], (0..8).map(|i| i as f32).collect()).unwrap();
        for bad_axis in [2usize, 7, usize::MAX] {
            let msg = format!("{:#}", t.split_half(bad_axis).unwrap_err());
            assert!(msg.contains("out of range"), "unexpected error: {msg}");
            let msg = format!("{:#}", t.concat(&t, bad_axis).unwrap_err());
            assert!(msg.contains("equal-rank"), "unexpected error: {msg}");
        }
        // odd and zero extents along the split axis
        for odd in [Tile::zeros(vec![3, 2]), Tile::zeros(vec![0, 2])] {
            let msg = format!("{:#}", odd.split_half(0).unwrap_err());
            assert!(msg.contains("even extent"), "unexpected error: {msg}");
        }
        // rank-0 tiles: every axis is out of range
        let scalarish = Tile::new(vec![], vec![1.0]).unwrap();
        assert!(scalarish.split_half(0).is_err());
        assert!(scalarish.concat(&scalarish, 0).is_err());
        // concat rank mismatch and off-axis extent mismatch
        let other_rank = Tile::zeros(vec![2, 4, 1]);
        let msg = format!("{:#}", t.concat(&other_rank, 0).unwrap_err());
        assert!(msg.contains("equal-rank"), "unexpected error: {msg}");
        let off_axis = Tile::zeros(vec![3, 4]);
        let msg = format!("{:#}", t.concat(&off_axis, 1).unwrap_err());
        assert!(msg.contains("off-axis"), "unexpected error: {msg}");
        // and the happy path still works after all that
        let (lo, hi) = t.split_half(1).unwrap();
        assert_eq!(lo.concat(&hi, 1).unwrap(), t);
    }

    #[test]
    fn dot_rejects_inner_dimension_mismatch() {
        let a = Tile::zeros(vec![2, 3]);
        let b = Tile::zeros(vec![4, 2]);
        for result in [a.dot(&b), a.dot_naive(&b), a.dot_blocked(&b)] {
            let msg = format!("{:#}", result.unwrap_err());
            assert!(msg.contains("inner-dimension"), "unexpected error: {msg}");
        }
    }

    #[test]
    fn blocked_dot_matches_naive_oracle() {
        use crate::prng::SplitMix64;
        let mut rng = SplitMix64::new(23);
        // 1x1, odd/prime shapes, ragged strips, and a shape above the
        // small-gemm threshold so the packed path runs too
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 7, 5),
            (13, 1, 9),
            (31, 65, 33),
            (127, 129, 65),
            (96, 96, 96),
        ] {
            let a = Tile::new(vec![m, k], rng.normal_vec(m * k)).unwrap();
            let b = Tile::new(vec![k, n], rng.normal_vec(k * n)).unwrap();
            let fast = a.dot_blocked(&b).unwrap();
            let slow = a.dot_naive(&b).unwrap();
            assert_eq!(fast.shape, slow.shape);
            let diff = fast
                .data
                .iter()
                .zip(&slow.data)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(diff <= 1e-3, "({m},{k},{n}): blocked vs naive max|diff| = {diff}");
        }
    }
}
