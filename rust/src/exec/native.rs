//! Native kernel resolution — now a thin façade over [`crate::kernel`].
//!
//! The hardcoded catalog that used to live here (a static slice of
//! hand-wired entries, each with bespoke arity, shape-check, specializer
//! and coalesce-flag code) was replaced by the first-class
//! `kernel::make(arrangement, application, tensors)` API: every builtin
//! is declared in [`crate::kernel::builtins`] and everything that was
//! hand-written here is derived by [`crate::kernel::make`].  This module
//! keeps the execution-side names (`lookup`, `kernels`,
//! [`Specialization`]) stable for the rest of the crate.

pub use crate::kernel::{kernels, lookup, KernelDef, Specialization};
