//! The native kernel catalog: tile programs + arrangement specializers
//! for the kernels the exec backend can compute without AOT artifacts.
//!
//! Each entry pairs a catalog arrangement (`crate::arrange::catalog`, the
//! paper Listings re-derived against the Rust tensor mirror) with a tile
//! program mirroring the Python application function.  Unlike artifacts,
//! native kernels are *shape-polymorphic*: specialization happens per
//! shape bucket, exactly as the DSL would re-specialize for a new shape.
//!
//! Specializers are functions of **shapes only** — no tensor data — which
//! is what lets `exec::compile` memoize the result in the plan cache:
//! a [`Specialization`] computed for `[m, k] x [k, n]` serves every later
//! request with those shapes, without re-lowering a single view.

use std::collections::BTreeMap;
use std::sync::OnceLock;

use anyhow::{bail, Result};

use super::ir::{Instr, TileProgram};
use super::scheduler::GridScheduler;
use super::tile::{BinOp, ReduceOp, UnaryOp};
use super::view::ParamView;
use crate::arrange::catalog;
use crate::runtime::HostTensor;
use crate::tensor::SymTensor;

/// A fully specialized launch: concrete views + output shapes.
pub struct Specialization {
    pub grid: Vec<i64>,
    pub loop_shape: Vec<usize>,
    pub views: Vec<ParamView>,
    pub output_shapes: Vec<Vec<usize>>,
}

impl Specialization {
    pub fn programs(&self) -> i64 {
        self.grid.iter().product::<i64>().max(1)
    }
}

pub struct NativeKernel {
    pub name: &'static str,
    /// number of input (non-output) parameters
    pub arity: usize,
    pub program: TileProgram,
    /// same-shape requests may be stacked along dim 0 into one launch
    /// (element-wise / row-independent kernels only): the batcher's native
    /// coalescing path consults this
    pub coalesce: bool,
    /// cheap shape preconditions (no lowering) — what admission runs
    shape_check: fn(&[&[usize]]) -> Result<()>,
    specialize: fn(&[&[usize]]) -> Result<Specialization>,
}

impl NativeKernel {
    /// Shape-only admission checks: arity, rank / zero-length dims, and
    /// the kernel's shape preconditions.  No affine lowering.
    pub fn check_shapes(&self, shapes: &[&[usize]]) -> Result<()> {
        if shapes.len() != self.arity {
            bail!("kernel {} expects {} inputs, got {}", self.name, self.arity, shapes.len());
        }
        for (i, s) in shapes.iter().enumerate() {
            if s.is_empty() {
                bail!(
                    "kernel {}: input {i} is rank-0 (scalar tensors are not tileable)",
                    self.name
                );
            }
            if s.iter().any(|&d| d == 0) {
                bail!("kernel {}: input {i} has a zero-length dimension {s:?}", self.name);
            }
        }
        (self.shape_check)(shapes)
    }

    /// Cheap admission-time validation over concrete tensors: the shape
    /// checks plus dtype.  The router calls this per request; the
    /// expensive specialization happens once per shape, in the compile
    /// stage.
    pub fn check(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.arity {
            bail!("kernel {} expects {} inputs, got {}", self.name, self.arity, inputs.len());
        }
        let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
        self.check_shapes(&shapes)?;
        for (i, t) in inputs.iter().enumerate() {
            t.as_f32()
                .map_err(|_| anyhow::anyhow!("kernel {}: input {i} must be f32", self.name))?;
        }
        Ok(())
    }

    /// Validate shapes and compute the concrete launch for them — the
    /// expensive stage `exec::compile` runs once per shape signature.
    pub fn specialize_shapes(&self, shapes: &[&[usize]]) -> Result<Specialization> {
        self.check_shapes(shapes)?;
        (self.specialize)(shapes)
    }

    /// Validate inputs and compute the concrete launch for them.
    pub fn specialize(&self, inputs: &[HostTensor]) -> Result<Specialization> {
        self.check(inputs)?;
        let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
        (self.specialize)(&shapes)
    }

    /// Compile-and-execute in one step (uncached — callers that serve
    /// repeated traffic go through `exec::PlanCache` instead).
    pub fn run(&self, inputs: &[HostTensor], scheduler: &GridScheduler) -> Result<Vec<HostTensor>> {
        let spec = self.specialize(inputs)?;
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        scheduler.run(&self.program, &spec.views, &refs, &spec.output_shapes)
    }
}

/// Look up a native kernel by name.
pub fn lookup(name: &str) -> Option<&'static NativeKernel> {
    kernels().iter().find(|k| k.name == name)
}

/// All native kernels.
pub fn kernels() -> &'static [NativeKernel] {
    static CATALOG: OnceLock<Vec<NativeKernel>> = OnceLock::new();
    CATALOG.get_or_init(build_catalog)
}

// -- specialization helpers ---------------------------------------------------

fn bind(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

/// Size bindings `<name>_size_<d>` for one parameter.
fn bind_sizes(bindings: &mut BTreeMap<String, i64>, name: &str, shape: &[usize]) {
    for (d, &s) in shape.iter().enumerate() {
        bindings.insert(format!("{name}_size_{d}"), s as i64);
    }
}

/// Element-wise block size: a power of two covering small inputs exactly.
fn elementwise_block(n: usize) -> i64 {
    (n.next_power_of_two() as i64).min(4096)
}

fn build_spec(
    tensors: &[SymTensor],
    bindings: &BTreeMap<String, i64>,
    shapes: &[&[usize]],
    is_output: &[bool],
    pad_values: &[f32],
) -> Result<Specialization> {
    let mut views = Vec::new();
    for (((t, shape), &out), &pad) in
        tensors.iter().zip(shapes).zip(is_output).zip(pad_values)
    {
        views.push(ParamView::specialize(t, bindings, shape, out, pad)?);
    }
    let grid = views[0].grid.clone();
    for v in &views {
        if v.grid != grid {
            bail!(
                "outermost-level shapes disagree: {:?} ({}) vs {grid:?} (paper §3.2.1)",
                v.grid,
                v.name
            );
        }
    }
    let mut loop_shape = Vec::new();
    for v in &views {
        if !v.loop_shape.is_empty() {
            if loop_shape.is_empty() {
                loop_shape = v.loop_shape.clone();
            } else if loop_shape != v.loop_shape {
                bail!("loop-level shapes disagree: {:?} ({})", v.loop_shape, v.name);
            }
        }
    }
    let output_shapes = views
        .iter()
        .zip(shapes)
        .filter(|(v, _)| v.is_output)
        .map(|(_, s)| s.to_vec())
        .collect();
    Ok(Specialization { grid, loop_shape, views, output_shapes })
}

// -- per-kernel shape preconditions -------------------------------------------

fn check_add(shapes: &[&[usize]]) -> Result<()> {
    let (a, b) = (shapes[0], shapes[1]);
    if a.len() != 1 || a != b {
        bail!("add expects two equal 1-D tensors, got {a:?} and {b:?}");
    }
    Ok(())
}

fn check_1d(shapes: &[&[usize]]) -> Result<()> {
    if shapes[0].len() != 1 {
        bail!("expected a 1-D tensor, got {:?}", shapes[0]);
    }
    Ok(())
}

fn check_2d(shapes: &[&[usize]]) -> Result<()> {
    if shapes[0].len() != 2 {
        bail!("expected a 2-D tensor, got {:?}", shapes[0]);
    }
    Ok(())
}

fn check_mm(shapes: &[&[usize]]) -> Result<()> {
    let (a, b) = (shapes[0], shapes[1]);
    if a.len() != 2 || b.len() != 2 || a[1] != b[0] {
        bail!("mm expects [m,k] x [k,n], got {a:?} and {b:?}");
    }
    Ok(())
}

fn check_bmm(shapes: &[&[usize]]) -> Result<()> {
    let (a, b) = (shapes[0], shapes[1]);
    if a.len() != 3 || b.len() != 3 || a[0] != b[0] || a[2] != b[1] {
        bail!("bmm expects [b,m,k] x [b,k,n], got {a:?} and {b:?}");
    }
    Ok(())
}

fn check_addmm(shapes: &[&[usize]]) -> Result<()> {
    let (bias, a, b) = (shapes[0], shapes[1], shapes[2]);
    if a.len() != 2 || b.len() != 2 || a[1] != b[0] {
        bail!("addmm expects mat1 [m,k] x mat2 [k,n], got {a:?} and {b:?}");
    }
    let (m, n) = (a[0], b[1]);
    let broadcastable = match bias.len() {
        1 => bias[0] == n,
        2 => (bias[0] == 1 || bias[0] == m) && bias[1] == n,
        _ => false,
    };
    if !broadcastable {
        bail!(
            "addmm bias {bias:?} does not broadcast to the [{m}, {n}] output \
             (expected [{n}], [1, {n}], or [{m}, {n}])"
        );
    }
    Ok(())
}

// -- per-kernel specializers --------------------------------------------------

fn spec_add(shapes: &[&[usize]]) -> Result<Specialization> {
    check_add(shapes)?;
    let a = shapes[0];
    let n = a[0];
    let tensors = catalog::add()?;
    let mut bindings = bind(&[("BLOCK_SIZE", elementwise_block(n))]);
    for name in ["input", "other", "output"] {
        bind_sizes(&mut bindings, name, a);
    }
    build_spec(&tensors, &bindings, &[a, a, a], &[false, false, true], &[0.0, 0.0, 0.0])
}

fn spec_silu(shapes: &[&[usize]]) -> Result<Specialization> {
    check_1d(shapes)?;
    let a = shapes[0];
    let tensors = catalog::elementwise_1d(&["input", "output"])?;
    let mut bindings = bind(&[("BLOCK_SIZE", elementwise_block(a[0]))]);
    bind_sizes(&mut bindings, "input", a);
    bind_sizes(&mut bindings, "output", a);
    build_spec(&tensors, &bindings, &[a, a], &[false, true], &[0.0, 0.0])
}

/// gelu shares silu's 1-D element-wise arrangement.
fn spec_gelu(shapes: &[&[usize]]) -> Result<Specialization> {
    spec_silu(shapes)
}

fn spec_rowwise(pad: f32, shapes: &[&[usize]]) -> Result<Specialization> {
    check_2d(shapes)?;
    let a = shapes[0];
    let tensors = catalog::rowwise()?;
    let mut bindings = BTreeMap::new();
    bind_sizes(&mut bindings, "input", a);
    bind_sizes(&mut bindings, "output", a);
    build_spec(&tensors, &bindings, &[a, a], &[false, true], &[pad, 0.0])
}

fn spec_softmax(shapes: &[&[usize]]) -> Result<Specialization> {
    spec_rowwise(f32::NEG_INFINITY, shapes)
}

fn spec_rms_norm(shapes: &[&[usize]]) -> Result<Specialization> {
    spec_rowwise(0.0, shapes)
}

/// layer_norm shares the rowwise arrangement (one program per row; the
/// block is the whole row, so no pad value ever participates).
fn spec_layer_norm(shapes: &[&[usize]]) -> Result<Specialization> {
    spec_rowwise(0.0, shapes)
}

const MM_BLOCK: i64 = 32;

/// Matmul tiling for concrete `[m, k] x [k, n]` sizes.  Small problems
/// keep the legacy 32-wide blocks (one gather per tile, no packing
/// overhead); larger ones take 64x64 output tiles with K panels up to
/// 256 deep, so the fused `DotAcc` GEMM amortizes packing while the grid
/// still fans out across the worker pool (8x8 cells for a 512^3 mm).
fn mm_blocks(m: usize, k: usize, n: usize) -> (i64, i64, i64) {
    if m.max(n).max(k) <= 128 {
        (MM_BLOCK, MM_BLOCK, MM_BLOCK)
    } else {
        (64, 64, k.min(256) as i64)
    }
}

fn spec_mm(shapes: &[&[usize]]) -> Result<Specialization> {
    check_mm(shapes)?;
    let (a, b) = (shapes[0], shapes[1]);
    let out = vec![a[0], b[1]];
    let tensors = catalog::mm()?;
    let (bm, bn, bk) = mm_blocks(a[0], a[1], b[1]);
    let mut bindings = bind(&[("BLOCK_SIZE_M", bm), ("BLOCK_SIZE_N", bn), ("BLOCK_SIZE_K", bk)]);
    bind_sizes(&mut bindings, "input", a);
    bind_sizes(&mut bindings, "other", b);
    bind_sizes(&mut bindings, "output", &out);
    build_spec(&tensors, &bindings, &[a, b, &out], &[false, false, true], &[0.0, 0.0, 0.0])
}

fn spec_bmm(shapes: &[&[usize]]) -> Result<Specialization> {
    check_bmm(shapes)?;
    let (a, b) = (shapes[0], shapes[1]);
    let out = vec![a[0], a[1], b[2]];
    let tensors = catalog::bmm()?;
    let (bm, bn, bk) = mm_blocks(a[1], a[2], b[2]);
    let mut bindings = bind(&[("BLOCK_SIZE_M", bm), ("BLOCK_SIZE_N", bn), ("BLOCK_SIZE_K", bk)]);
    bind_sizes(&mut bindings, "input", a);
    bind_sizes(&mut bindings, "other", b);
    bind_sizes(&mut bindings, "output", &out);
    build_spec(&tensors, &bindings, &[a, b, &out], &[false, false, true], &[0.0, 0.0, 0.0])
}

/// addmm = mm + broadcast bias epilogue.  A rank-1 (or `[1, n]`) bias
/// lowers as a `[1, n]` view whose row-grid dimension is expanded —
/// every output row tile loads the same bias tile; a full `[m, n]` bias
/// is tiled exactly like the output.
fn spec_addmm(shapes: &[&[usize]]) -> Result<Specialization> {
    check_addmm(shapes)?;
    let (bias, a, b) = (shapes[0], shapes[1], shapes[2]);
    let out = vec![a[0], b[1]];
    let bias2d: Vec<usize> = if bias.len() == 1 { vec![1, bias[0]] } else { bias.to_vec() };
    let row_bias = bias2d[0] == 1;
    let tensors = catalog::addmm(row_bias)?;
    let (bm, bn, bk) = mm_blocks(a[0], a[1], b[1]);
    let mut bindings = bind(&[("BLOCK_SIZE_M", bm), ("BLOCK_SIZE_N", bn), ("BLOCK_SIZE_K", bk)]);
    bind_sizes(&mut bindings, "bias", &bias2d);
    bind_sizes(&mut bindings, "input", a);
    bind_sizes(&mut bindings, "other", b);
    bind_sizes(&mut bindings, "output", &out);
    build_spec(
        &tensors,
        &bindings,
        &[&bias2d, a, b, &out],
        &[false, false, false, true],
        &[0.0, 0.0, 0.0, 0.0],
    )
}

// -- tile programs ------------------------------------------------------------

fn program_add() -> TileProgram {
    TileProgram {
        name: "add",
        regs: 3,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Load { dst: 1, param: 1 },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Add },
            Instr::Store { param: 2, src: 2 },
        ],
    }
}

fn program_silu() -> TileProgram {
    TileProgram {
        name: "silu",
        regs: 3,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Unary { dst: 1, a: 0, op: UnaryOp::Sigmoid },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Mul },
            Instr::Store { param: 1, src: 2 },
        ],
    }
}

/// tanh-approximated GELU via the identity `1 + tanh(y) = 2*sigmoid(2y)`:
/// `gelu(x) = 0.5*x*(1 + tanh(sqrt(2/pi)*(x + 0.044715*x^3)))
///          = x * sigmoid(2*sqrt(2/pi)*(x + 0.044715*x^3))`,
/// which needs only the existing Mul/Add/Const/Sigmoid ops.
fn program_gelu() -> TileProgram {
    // 2 * sqrt(2 / pi)
    const TWO_SQRT_2_OVER_PI: f32 = 1.595_769_1;
    const CUBIC: f32 = 0.044_715;
    TileProgram {
        name: "gelu",
        regs: 10,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Binary { dst: 1, a: 0, b: 0, op: BinOp::Mul },
            Instr::Binary { dst: 2, a: 1, b: 0, op: BinOp::Mul },
            Instr::Const { dst: 3, value: CUBIC },
            Instr::Binary { dst: 4, a: 2, b: 3, op: BinOp::Mul },
            Instr::Binary { dst: 5, a: 0, b: 4, op: BinOp::Add },
            Instr::Const { dst: 6, value: TWO_SQRT_2_OVER_PI },
            Instr::Binary { dst: 7, a: 5, b: 6, op: BinOp::Mul },
            Instr::Unary { dst: 8, a: 7, op: UnaryOp::Sigmoid },
            Instr::Binary { dst: 9, a: 0, b: 8, op: BinOp::Mul },
            Instr::Store { param: 1, src: 9 },
        ],
    }
}

fn program_softmax() -> TileProgram {
    TileProgram {
        name: "softmax",
        regs: 6,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Reduce { dst: 1, a: 0, axis: None, op: ReduceOp::Max },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Sub },
            Instr::Unary { dst: 3, a: 2, op: UnaryOp::Exp },
            Instr::Reduce { dst: 4, a: 3, axis: None, op: ReduceOp::Sum },
            Instr::Binary { dst: 5, a: 3, b: 4, op: BinOp::Div },
            Instr::Store { param: 1, src: 5 },
        ],
    }
}

fn program_rms_norm() -> TileProgram {
    TileProgram {
        name: "rms_norm",
        regs: 7,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Binary { dst: 1, a: 0, b: 0, op: BinOp::Mul },
            Instr::Reduce { dst: 2, a: 1, axis: None, op: ReduceOp::Mean },
            Instr::Const { dst: 3, value: 1e-6 },
            Instr::Binary { dst: 4, a: 2, b: 3, op: BinOp::Add },
            Instr::Unary { dst: 5, a: 4, op: UnaryOp::Rsqrt },
            Instr::Binary { dst: 6, a: 0, b: 5, op: BinOp::Mul },
            Instr::Store { param: 1, src: 6 },
        ],
    }
}

/// `layer_norm(x) = (x - mean(x)) * rsqrt(var(x) + eps)` over each row
/// (no affine weight/bias, eps = 1e-6 — consistent with rms_norm).
fn program_layer_norm() -> TileProgram {
    TileProgram {
        name: "layer_norm",
        regs: 9,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Reduce { dst: 1, a: 0, axis: None, op: ReduceOp::Mean },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Sub },
            Instr::Binary { dst: 3, a: 2, b: 2, op: BinOp::Mul },
            Instr::Reduce { dst: 4, a: 3, axis: None, op: ReduceOp::Mean },
            Instr::Const { dst: 5, value: 1e-6 },
            Instr::Binary { dst: 6, a: 4, b: 5, op: BinOp::Add },
            Instr::Unary { dst: 7, a: 6, op: UnaryOp::Rsqrt },
            Instr::Binary { dst: 8, a: 2, b: 7, op: BinOp::Mul },
            Instr::Store { param: 1, src: 8 },
        ],
    }
}

/// The mm/bmm application: `acc = zeros(output.shape); for k: acc +=
/// dot(input[k], other[k]); output = acc` — identical for both kernels
/// because the arrangements reduce both to the same tile-level view.
/// The k-loop body is the fused `DotAcc`, which consumes the parameter
/// views directly through the blocked GEMM (no materialized tiles on
/// dense interior cells; gather fallback at padded edges).
fn program_matmul(name: &'static str) -> TileProgram {
    TileProgram {
        name,
        regs: 1,
        instrs: vec![
            Instr::Zeros { dst: 0, like_param: 2 },
            Instr::Loop { body: vec![Instr::DotAcc { acc: 0, a_param: 0, b_param: 1 }] },
            Instr::Store { param: 2, src: 0 },
        ],
    }
}

/// The addmm application: the mm k-loop followed by a broadcast bias add
/// (`output = acc + bias`).  Parameters are `[bias, input, other, output]`
/// (torch.addmm argument order, output last); the bias tile is `[1, BN]`
/// for broadcast biases and `[BM, BN]` for full ones — the element-wise
/// add broadcasts either onto the accumulator.
fn program_addmm() -> TileProgram {
    TileProgram {
        name: "addmm",
        regs: 3,
        instrs: vec![
            Instr::Zeros { dst: 0, like_param: 3 },
            Instr::Loop { body: vec![Instr::DotAcc { acc: 0, a_param: 1, b_param: 2 }] },
            Instr::Load { dst: 1, param: 0 },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Add },
            Instr::Store { param: 3, src: 2 },
        ],
    }
}

fn build_catalog() -> Vec<NativeKernel> {
    vec![
        NativeKernel {
            name: "add",
            arity: 2,
            program: program_add(),
            coalesce: true,
            shape_check: check_add,
            specialize: spec_add,
        },
        NativeKernel {
            name: "silu",
            arity: 1,
            program: program_silu(),
            coalesce: true,
            shape_check: check_1d,
            specialize: spec_silu,
        },
        NativeKernel {
            name: "gelu",
            arity: 1,
            program: program_gelu(),
            coalesce: true,
            shape_check: check_1d,
            specialize: spec_gelu,
        },
        NativeKernel {
            name: "softmax",
            arity: 1,
            program: program_softmax(),
            coalesce: true,
            shape_check: check_2d,
            specialize: spec_softmax,
        },
        NativeKernel {
            name: "rms_norm",
            arity: 1,
            program: program_rms_norm(),
            coalesce: true,
            shape_check: check_2d,
            specialize: spec_rms_norm,
        },
        NativeKernel {
            name: "layer_norm",
            arity: 1,
            program: program_layer_norm(),
            coalesce: true,
            shape_check: check_2d,
            specialize: spec_layer_norm,
        },
        NativeKernel {
            name: "mm",
            arity: 2,
            program: program_matmul("mm"),
            coalesce: false,
            shape_check: check_mm,
            specialize: spec_mm,
        },
        NativeKernel {
            name: "bmm",
            arity: 2,
            program: program_matmul("bmm"),
            coalesce: false,
            shape_check: check_bmm,
            specialize: spec_bmm,
        },
        NativeKernel {
            name: "addmm",
            arity: 3,
            program: program_addmm(),
            coalesce: false,
            shape_check: check_addmm,
            specialize: spec_addmm,
        },
    ]
}
