//! The native kernel catalog: tile programs + arrangement specializers
//! for the kernels the exec backend can compute without AOT artifacts.
//!
//! Each entry pairs a catalog arrangement (`crate::arrange::catalog`, the
//! paper Listings re-derived against the Rust tensor mirror) with a tile
//! program mirroring the Python application function.  Unlike artifacts,
//! native kernels are *shape-polymorphic*: specialization happens per
//! request from the concrete input shapes, exactly as the DSL would
//! re-specialize for a new shape bucket.

use std::collections::BTreeMap;
use std::sync::OnceLock;

use anyhow::{bail, Result};

use super::ir::{Instr, TileProgram};
use super::scheduler::GridScheduler;
use super::tile::{BinOp, ReduceOp, UnaryOp};
use super::view::ParamView;
use crate::arrange::catalog;
use crate::runtime::HostTensor;
use crate::tensor::SymTensor;

/// A fully specialized launch: concrete views + output shapes.
pub struct Specialization {
    pub grid: Vec<i64>,
    pub loop_shape: Vec<usize>,
    pub views: Vec<ParamView>,
    pub output_shapes: Vec<Vec<usize>>,
}

impl Specialization {
    pub fn programs(&self) -> i64 {
        self.grid.iter().product::<i64>().max(1)
    }
}

pub struct NativeKernel {
    pub name: &'static str,
    /// number of input (non-output) parameters
    pub arity: usize,
    pub program: TileProgram,
    /// cheap shape preconditions (no lowering) — what admission runs
    shape_check: fn(&[HostTensor]) -> Result<()>,
    specialize: fn(&[HostTensor]) -> Result<Specialization>,
}

impl NativeKernel {
    /// Cheap admission-time validation: arity, dtype, rank / zero-length
    /// dims, and the kernel's shape preconditions.  No affine lowering —
    /// the router calls this per request; the expensive specialization
    /// happens once, on the worker.
    pub fn check(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.arity {
            bail!("kernel {} expects {} inputs, got {}", self.name, self.arity, inputs.len());
        }
        for (i, t) in inputs.iter().enumerate() {
            if t.shape.is_empty() {
                bail!("kernel {}: input {i} is rank-0 (scalar tensors are not tileable)", self.name);
            }
            if t.shape.iter().any(|&d| d == 0) {
                bail!("kernel {}: input {i} has a zero-length dimension {:?}", self.name, t.shape);
            }
            t.as_f32()
                .map_err(|_| anyhow::anyhow!("kernel {}: input {i} must be f32", self.name))?;
        }
        (self.shape_check)(inputs)
    }

    /// Validate inputs and compute the concrete launch for them.
    pub fn specialize(&self, inputs: &[HostTensor]) -> Result<Specialization> {
        self.check(inputs)?;
        (self.specialize)(inputs)
    }

    /// Execute natively under the given scheduler.
    pub fn run(&self, inputs: &[HostTensor], scheduler: &GridScheduler) -> Result<Vec<HostTensor>> {
        let spec = self.specialize(inputs)?;
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        scheduler.run(&self.program, &spec.views, &refs, &spec.output_shapes)
    }
}

/// Look up a native kernel by name.
pub fn lookup(name: &str) -> Option<&'static NativeKernel> {
    kernels().iter().find(|k| k.name == name)
}

/// All native kernels.
pub fn kernels() -> &'static [NativeKernel] {
    static CATALOG: OnceLock<Vec<NativeKernel>> = OnceLock::new();
    CATALOG.get_or_init(build_catalog)
}

// -- specialization helpers ---------------------------------------------------

fn bind(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

/// Size bindings `<name>_size_<d>` for one parameter.
fn bind_sizes(bindings: &mut BTreeMap<String, i64>, name: &str, shape: &[usize]) {
    for (d, &s) in shape.iter().enumerate() {
        bindings.insert(format!("{name}_size_{d}"), s as i64);
    }
}

/// Element-wise block size: a power of two covering small inputs exactly.
fn elementwise_block(n: usize) -> i64 {
    (n.next_power_of_two() as i64).min(4096)
}

fn build_spec(
    tensors: &[SymTensor],
    bindings: &BTreeMap<String, i64>,
    shapes: &[&[usize]],
    is_output: &[bool],
    pad_values: &[f32],
) -> Result<Specialization> {
    let mut views = Vec::new();
    for (((t, shape), &out), &pad) in
        tensors.iter().zip(shapes).zip(is_output).zip(pad_values)
    {
        views.push(ParamView::specialize(t, bindings, shape, out, pad)?);
    }
    let grid = views[0].grid.clone();
    for v in &views {
        if v.grid != grid {
            bail!(
                "outermost-level shapes disagree: {:?} ({}) vs {grid:?} (paper §3.2.1)",
                v.grid,
                v.name
            );
        }
    }
    let mut loop_shape = Vec::new();
    for v in &views {
        if !v.loop_shape.is_empty() {
            if loop_shape.is_empty() {
                loop_shape = v.loop_shape.clone();
            } else if loop_shape != v.loop_shape {
                bail!("loop-level shapes disagree: {:?} ({})", v.loop_shape, v.name);
            }
        }
    }
    let output_shapes = views
        .iter()
        .zip(shapes)
        .filter(|(v, _)| v.is_output)
        .map(|(_, s)| s.to_vec())
        .collect();
    Ok(Specialization { grid, loop_shape, views, output_shapes })
}

// -- per-kernel shape preconditions -------------------------------------------

fn check_add(inputs: &[HostTensor]) -> Result<()> {
    let (a, b) = (&inputs[0], &inputs[1]);
    if a.shape.len() != 1 || a.shape != b.shape {
        bail!("add expects two equal 1-D tensors, got {:?} and {:?}", a.shape, b.shape);
    }
    Ok(())
}

fn check_1d(inputs: &[HostTensor]) -> Result<()> {
    if inputs[0].shape.len() != 1 {
        bail!("expected a 1-D tensor, got {:?}", inputs[0].shape);
    }
    Ok(())
}

fn check_2d(inputs: &[HostTensor]) -> Result<()> {
    if inputs[0].shape.len() != 2 {
        bail!("expected a 2-D tensor, got {:?}", inputs[0].shape);
    }
    Ok(())
}

fn check_mm(inputs: &[HostTensor]) -> Result<()> {
    let (a, b) = (&inputs[0], &inputs[1]);
    if a.shape.len() != 2 || b.shape.len() != 2 || a.shape[1] != b.shape[0] {
        bail!("mm expects [m,k] x [k,n], got {:?} and {:?}", a.shape, b.shape);
    }
    Ok(())
}

fn check_bmm(inputs: &[HostTensor]) -> Result<()> {
    let (a, b) = (&inputs[0], &inputs[1]);
    if a.shape.len() != 3
        || b.shape.len() != 3
        || a.shape[0] != b.shape[0]
        || a.shape[2] != b.shape[1]
    {
        bail!("bmm expects [b,m,k] x [b,k,n], got {:?} and {:?}", a.shape, b.shape);
    }
    Ok(())
}

// -- per-kernel specializers --------------------------------------------------

fn spec_add(inputs: &[HostTensor]) -> Result<Specialization> {
    check_add(inputs)?;
    let a = &inputs[0];
    let n = a.shape[0];
    let tensors = catalog::add()?;
    let mut bindings = bind(&[("BLOCK_SIZE", elementwise_block(n))]);
    for name in ["input", "other", "output"] {
        bind_sizes(&mut bindings, name, &a.shape);
    }
    build_spec(
        &tensors,
        &bindings,
        &[&a.shape, &a.shape, &a.shape],
        &[false, false, true],
        &[0.0, 0.0, 0.0],
    )
}

fn spec_silu(inputs: &[HostTensor]) -> Result<Specialization> {
    check_1d(inputs)?;
    let a = &inputs[0];
    let tensors = catalog::elementwise_1d(&["input", "output"])?;
    let mut bindings = bind(&[("BLOCK_SIZE", elementwise_block(a.shape[0]))]);
    bind_sizes(&mut bindings, "input", &a.shape);
    bind_sizes(&mut bindings, "output", &a.shape);
    build_spec(&tensors, &bindings, &[&a.shape, &a.shape], &[false, true], &[0.0, 0.0])
}

/// gelu shares silu's 1-D element-wise arrangement.
fn spec_gelu(inputs: &[HostTensor]) -> Result<Specialization> {
    spec_silu(inputs)
}

fn spec_rowwise(pad: f32, inputs: &[HostTensor]) -> Result<Specialization> {
    check_2d(inputs)?;
    let a = &inputs[0];
    let tensors = catalog::rowwise()?;
    let mut bindings = BTreeMap::new();
    bind_sizes(&mut bindings, "input", &a.shape);
    bind_sizes(&mut bindings, "output", &a.shape);
    build_spec(&tensors, &bindings, &[&a.shape, &a.shape], &[false, true], &[pad, 0.0])
}

fn spec_softmax(inputs: &[HostTensor]) -> Result<Specialization> {
    spec_rowwise(f32::NEG_INFINITY, inputs)
}

fn spec_rms_norm(inputs: &[HostTensor]) -> Result<Specialization> {
    spec_rowwise(0.0, inputs)
}

/// layer_norm shares the rowwise arrangement (one program per row; the
/// block is the whole row, so no pad value ever participates).
fn spec_layer_norm(inputs: &[HostTensor]) -> Result<Specialization> {
    spec_rowwise(0.0, inputs)
}

const MM_BLOCK: i64 = 32;

/// Matmul tiling for concrete `[m, k] x [k, n]` sizes.  Small problems
/// keep the legacy 32-wide blocks (one gather per tile, no packing
/// overhead); larger ones take 64x64 output tiles with K panels up to
/// 256 deep, so the fused `DotAcc` GEMM amortizes packing while the grid
/// still fans out across the worker pool (8x8 cells for a 512^3 mm).
fn mm_blocks(m: usize, k: usize, n: usize) -> (i64, i64, i64) {
    if m.max(n).max(k) <= 128 {
        (MM_BLOCK, MM_BLOCK, MM_BLOCK)
    } else {
        (64, 64, k.min(256) as i64)
    }
}

fn spec_mm(inputs: &[HostTensor]) -> Result<Specialization> {
    check_mm(inputs)?;
    let (a, b) = (&inputs[0], &inputs[1]);
    let out = vec![a.shape[0], b.shape[1]];
    let tensors = catalog::mm()?;
    let (bm, bn, bk) = mm_blocks(a.shape[0], a.shape[1], b.shape[1]);
    let mut bindings = bind(&[("BLOCK_SIZE_M", bm), ("BLOCK_SIZE_N", bn), ("BLOCK_SIZE_K", bk)]);
    bind_sizes(&mut bindings, "input", &a.shape);
    bind_sizes(&mut bindings, "other", &b.shape);
    bind_sizes(&mut bindings, "output", &out);
    build_spec(
        &tensors,
        &bindings,
        &[&a.shape, &b.shape, &out],
        &[false, false, true],
        &[0.0, 0.0, 0.0],
    )
}

fn spec_bmm(inputs: &[HostTensor]) -> Result<Specialization> {
    check_bmm(inputs)?;
    let (a, b) = (&inputs[0], &inputs[1]);
    let out = vec![a.shape[0], a.shape[1], b.shape[2]];
    let tensors = catalog::bmm()?;
    let (bm, bn, bk) = mm_blocks(a.shape[1], a.shape[2], b.shape[2]);
    let mut bindings = bind(&[("BLOCK_SIZE_M", bm), ("BLOCK_SIZE_N", bn), ("BLOCK_SIZE_K", bk)]);
    bind_sizes(&mut bindings, "input", &a.shape);
    bind_sizes(&mut bindings, "other", &b.shape);
    bind_sizes(&mut bindings, "output", &out);
    build_spec(
        &tensors,
        &bindings,
        &[&a.shape, &b.shape, &out],
        &[false, false, true],
        &[0.0, 0.0, 0.0],
    )
}

// -- tile programs ------------------------------------------------------------

fn program_add() -> TileProgram {
    TileProgram {
        name: "add",
        regs: 3,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Load { dst: 1, param: 1 },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Add },
            Instr::Store { param: 2, src: 2 },
        ],
    }
}

fn program_silu() -> TileProgram {
    TileProgram {
        name: "silu",
        regs: 3,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Unary { dst: 1, a: 0, op: UnaryOp::Sigmoid },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Mul },
            Instr::Store { param: 1, src: 2 },
        ],
    }
}

/// tanh-approximated GELU via the identity `1 + tanh(y) = 2*sigmoid(2y)`:
/// `gelu(x) = 0.5*x*(1 + tanh(sqrt(2/pi)*(x + 0.044715*x^3)))
///          = x * sigmoid(2*sqrt(2/pi)*(x + 0.044715*x^3))`,
/// which needs only the existing Mul/Add/Const/Sigmoid ops.
fn program_gelu() -> TileProgram {
    // 2 * sqrt(2 / pi)
    const TWO_SQRT_2_OVER_PI: f32 = 1.595_769_1;
    const CUBIC: f32 = 0.044_715;
    TileProgram {
        name: "gelu",
        regs: 10,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Binary { dst: 1, a: 0, b: 0, op: BinOp::Mul },
            Instr::Binary { dst: 2, a: 1, b: 0, op: BinOp::Mul },
            Instr::Const { dst: 3, value: CUBIC },
            Instr::Binary { dst: 4, a: 2, b: 3, op: BinOp::Mul },
            Instr::Binary { dst: 5, a: 0, b: 4, op: BinOp::Add },
            Instr::Const { dst: 6, value: TWO_SQRT_2_OVER_PI },
            Instr::Binary { dst: 7, a: 5, b: 6, op: BinOp::Mul },
            Instr::Unary { dst: 8, a: 7, op: UnaryOp::Sigmoid },
            Instr::Binary { dst: 9, a: 0, b: 8, op: BinOp::Mul },
            Instr::Store { param: 1, src: 9 },
        ],
    }
}

fn program_softmax() -> TileProgram {
    TileProgram {
        name: "softmax",
        regs: 6,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Reduce { dst: 1, a: 0, axis: None, op: ReduceOp::Max },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Sub },
            Instr::Unary { dst: 3, a: 2, op: UnaryOp::Exp },
            Instr::Reduce { dst: 4, a: 3, axis: None, op: ReduceOp::Sum },
            Instr::Binary { dst: 5, a: 3, b: 4, op: BinOp::Div },
            Instr::Store { param: 1, src: 5 },
        ],
    }
}

fn program_rms_norm() -> TileProgram {
    TileProgram {
        name: "rms_norm",
        regs: 7,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Binary { dst: 1, a: 0, b: 0, op: BinOp::Mul },
            Instr::Reduce { dst: 2, a: 1, axis: None, op: ReduceOp::Mean },
            Instr::Const { dst: 3, value: 1e-6 },
            Instr::Binary { dst: 4, a: 2, b: 3, op: BinOp::Add },
            Instr::Unary { dst: 5, a: 4, op: UnaryOp::Rsqrt },
            Instr::Binary { dst: 6, a: 0, b: 5, op: BinOp::Mul },
            Instr::Store { param: 1, src: 6 },
        ],
    }
}

/// `layer_norm(x) = (x - mean(x)) * rsqrt(var(x) + eps)` over each row
/// (no affine weight/bias, eps = 1e-6 — consistent with rms_norm).
fn program_layer_norm() -> TileProgram {
    TileProgram {
        name: "layer_norm",
        regs: 9,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Reduce { dst: 1, a: 0, axis: None, op: ReduceOp::Mean },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Sub },
            Instr::Binary { dst: 3, a: 2, b: 2, op: BinOp::Mul },
            Instr::Reduce { dst: 4, a: 3, axis: None, op: ReduceOp::Mean },
            Instr::Const { dst: 5, value: 1e-6 },
            Instr::Binary { dst: 6, a: 4, b: 5, op: BinOp::Add },
            Instr::Unary { dst: 7, a: 6, op: UnaryOp::Rsqrt },
            Instr::Binary { dst: 8, a: 2, b: 7, op: BinOp::Mul },
            Instr::Store { param: 1, src: 8 },
        ],
    }
}

/// The mm/bmm application: `acc = zeros(output.shape); for k: acc +=
/// dot(input[k], other[k]); output = acc` — identical for both kernels
/// because the arrangements reduce both to the same tile-level view.
/// The k-loop body is the fused `DotAcc`, which consumes the parameter
/// views directly through the blocked GEMM (no materialized tiles on
/// dense interior cells; gather fallback at padded edges).
fn program_matmul(name: &'static str) -> TileProgram {
    TileProgram {
        name,
        regs: 1,
        instrs: vec![
            Instr::Zeros { dst: 0, like_param: 2 },
            Instr::Loop { body: vec![Instr::DotAcc { acc: 0, a_param: 0, b_param: 1 }] },
            Instr::Store { param: 2, src: 0 },
        ],
    }
}

fn build_catalog() -> Vec<NativeKernel> {
    vec![
        NativeKernel {
            name: "add",
            arity: 2,
            program: program_add(),
            shape_check: check_add,
            specialize: spec_add,
        },
        NativeKernel {
            name: "silu",
            arity: 1,
            program: program_silu(),
            shape_check: check_1d,
            specialize: spec_silu,
        },
        NativeKernel {
            name: "gelu",
            arity: 1,
            program: program_gelu(),
            shape_check: check_1d,
            specialize: spec_gelu,
        },
        NativeKernel {
            name: "softmax",
            arity: 1,
            program: program_softmax(),
            shape_check: check_2d,
            specialize: spec_softmax,
        },
        NativeKernel {
            name: "rms_norm",
            arity: 1,
            program: program_rms_norm(),
            shape_check: check_2d,
            specialize: spec_rms_norm,
        },
        NativeKernel {
            name: "layer_norm",
            arity: 1,
            program: program_layer_norm(),
            shape_check: check_2d,
            specialize: spec_layer_norm,
        },
        NativeKernel {
            name: "mm",
            arity: 2,
            program: program_matmul("mm"),
            shape_check: check_mm,
            specialize: spec_mm,
        },
        NativeKernel {
            name: "bmm",
            arity: 2,
            program: program_matmul("bmm"),
            shape_check: check_bmm,
            specialize: spec_bmm,
        },
    ]
}
