//! Persistent worker pool: the one set of OS threads every parallel
//! execution in the crate shares.
//!
//! Before this module, the grid scheduler and `DotAcc`'s intra-tile row
//! split each spawned *scoped* threads per run — every request paid
//! thread creation on the hot path, and concurrent requests oversubscribed
//! the machine with transient threads.  The pool inverts that: `NT`
//! worker threads are spawned once (lazily, on first parallel execution)
//! and live for the process; a run hands them a batch of borrowed jobs
//! through [`WorkerPool::run_scoped`] and blocks until all of them finish.
//!
//! Work-stealing-ish: jobs go into one shared injector queue, idle workers
//! pull from it, and the *submitting* thread helps drain the queue while
//! its own scope is unfinished instead of just blocking.  That last part
//! is what makes nested scopes safe (a job that itself calls `run_scoped`
//! keeps making progress by executing queued jobs, including its own) and
//! what keeps a `threads = N` pool delivering N+1-way parallelism.
//!
//! # Safety
//!
//! `run_scoped` accepts jobs borrowing the caller's stack (`'scope`
//! lifetimes) and erases the lifetime to move them through the `'static`
//! queue.  This is sound for the same reason `std::thread::scope` is:
//! the function does not return until every submitted job has completed
//! (panicked jobs included — panics are caught, counted, and re-thrown in
//! the caller), so no borrow outlives the data it references.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use anyhow::{anyhow, Result};

use crate::obs::PoolGauges;

/// A lifetime-erased job (see module docs for why `'static` is sound).
type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    queue: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// signaled when work arrives or shutdown begins
    work: Condvar,
    /// workers currently executing a job (observability gauge)
    busy: AtomicUsize,
    /// jobs executed from the queue since the pool started
    jobs: AtomicU64,
}

/// One scope of jobs submitted together: a countdown latch plus the first
/// caught panic, re-thrown by the submitting thread.
struct ScopeState {
    remaining: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` persistent threads (0 = everything runs inline on
    /// the submitting thread).
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState { queue: VecDeque::new(), shutdown: false }),
            work: Condvar::new(),
            busy: AtomicUsize::new(0),
            jobs: AtomicU64::new(0),
        });
        let workers = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("nt-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, workers }
    }

    /// Number of persistent worker threads (the submitting thread adds one
    /// more runner on top during `run_scoped`).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Point-in-time utilization gauges (queue depth, busy workers,
    /// lifetime job count).
    pub fn gauges(&self) -> PoolGauges {
        PoolGauges {
            workers: self.workers.len(),
            queue_depth: self.shared.state.lock().unwrap().queue.len(),
            busy_workers: self.shared.busy.load(Ordering::Relaxed),
            jobs_executed: self.shared.jobs.load(Ordering::Relaxed),
        }
    }

    /// Run every job to completion, in parallel across the pool plus the
    /// calling thread.  Returns only when all jobs have finished; if any
    /// job panicked, the first payload is re-thrown here.
    pub fn run_scoped<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if tasks.len() <= 1 || self.workers.is_empty() {
            for task in tasks {
                task();
            }
            return;
        }
        let scope = Arc::new(ScopeState {
            remaining: Mutex::new(tasks.len()),
            done: Condvar::new(),
            panic: Mutex::new(None),
        });
        {
            let mut state = self.shared.state.lock().unwrap();
            for task in tasks {
                // SAFETY: the job is only a queue entry until some thread
                // runs it, and this function blocks on the scope latch
                // until every job has run — the `'scope` borrows cannot
                // outlive the caller's frame (same argument as
                // `std::thread::scope`).
                let task: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(task)
                };
                let scope = scope.clone();
                state.queue.push_back(Box::new(move || {
                    if let Err(payload) =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(task))
                    {
                        let mut slot = scope.panic.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(payload);
                        }
                    }
                    let mut remaining = scope.remaining.lock().unwrap();
                    *remaining -= 1;
                    if *remaining == 0 {
                        scope.done.notify_all();
                    }
                }));
            }
            self.shared.work.notify_all();
        }
        // help: drain queued jobs (ours or another scope's) while this
        // scope is unfinished, then wait out the stragglers workers hold
        loop {
            if *scope.remaining.lock().unwrap() == 0 {
                break;
            }
            let job = self.shared.state.lock().unwrap().queue.pop_front();
            match job {
                Some(job) => {
                    job();
                    self.shared.jobs.fetch_add(1, Ordering::Relaxed);
                }
                None => {
                    let mut remaining = scope.remaining.lock().unwrap();
                    while *remaining > 0 {
                        remaining = scope.done.wait(remaining).unwrap();
                    }
                    break;
                }
            }
        }
        if let Some(payload) = scope.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut state = shared.state.lock().unwrap();
            loop {
                if let Some(job) = state.queue.pop_front() {
                    break Some(job);
                }
                if state.shutdown {
                    break None;
                }
                state = shared.work.wait(state).unwrap();
            }
        };
        match job {
            Some(job) => {
                shared.busy.fetch_add(1, Ordering::Relaxed);
                job();
                shared.busy.fetch_sub(1, Ordering::Relaxed);
                shared.jobs.fetch_add(1, Ordering::Relaxed);
            }
            None => return,
        }
    }
}

// -- the process-global pool --------------------------------------------------

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
static GLOBAL_SIZE: OnceLock<usize> = OnceLock::new();

/// Default pool width: one worker per hardware thread.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parse a positive-integer environment variable; `Ok(None)` when unset.
/// The clean-error half of the config satellite: garbage values fail
/// loudly at startup instead of being silently replaced by a default.
pub fn parse_env_usize(name: &str) -> Result<Option<usize>> {
    match std::env::var(name) {
        Err(_) => Ok(None),
        Ok(raw) => crate::cli::parse_positive(&raw)
            .map(Some)
            .ok_or_else(|| anyhow!("{name} must be a positive integer, got {raw:?}")),
    }
}

/// The pool width the global pool will use: `NT_POOL_THREADS` when set
/// (validated), [`default_threads`] otherwise.  The coordinator calls
/// this at startup so a malformed value is a clean startup error.
pub fn configured_threads() -> Result<usize> {
    Ok(parse_env_usize("NT_POOL_THREADS")?.unwrap_or_else(default_threads))
}

/// Pin the global pool's width before first use (server `--pool-threads`
/// flag).  Returns false when the width was already fixed — by an earlier
/// call or because the pool is already running.
pub fn init_global(workers: usize) -> bool {
    GLOBAL_SIZE.set(workers.max(1)).is_ok() && GLOBAL.get().is_none()
}

/// The process-global pool, created on first use.
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| {
        // fail loud on a malformed NT_POOL_THREADS even on paths that
        // never pass through `Coordinator::start` (benches, bare
        // `run_native` callers): this knob is documented as never being
        // silently defaulted
        let size = GLOBAL_SIZE.get().copied().unwrap_or_else(|| match configured_threads() {
            Ok(size) => size,
            Err(e) => panic!("{e:#}"),
        });
        WorkerPool::new(size)
    })
}

/// Gauges for the global pool *without* forcing its creation: all zeros
/// until some parallel execution has instantiated it.
pub fn global_gauges() -> PoolGauges {
    GLOBAL.get().map(WorkerPool::gauges).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_job_and_supports_borrows() {
        let pool = WorkerPool::new(3);
        let mut out = vec![0usize; 64];
        {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, slot) in out.iter_mut().enumerate() {
                tasks.push(Box::new(move || *slot = i + 1));
            }
            pool.run_scoped(tasks);
        }
        assert!(out.iter().enumerate().all(|(i, &v)| v == i + 1));
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        let pool = Arc::new(WorkerPool::new(2));
        let hits = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let (pool, hits) = (pool.clone(), &hits);
                Box::new(move || {
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                        .map(|_| {
                            Box::new(|| {
                                hits.fetch_add(1, Ordering::Relaxed);
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    pool.run_scoped(inner);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn panics_propagate_to_the_caller() {
        let pool = WorkerPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("boom")),
                Box::new(|| {}),
            ];
            pool.run_scoped(tasks);
        }));
        assert!(result.is_err(), "worker panic must surface in run_scoped");
    }

    #[test]
    fn gauges_count_executed_jobs() {
        let pool = WorkerPool::new(2);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
            (0..8).map(|_| Box::new(|| {}) as Box<dyn FnOnce() + Send + '_>).collect();
        pool.run_scoped(tasks);
        let g = pool.gauges();
        assert_eq!(g.workers, 2);
        assert_eq!(g.queue_depth, 0, "scope completion drains the queue");
        assert_eq!(g.jobs_executed, 8);
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = WorkerPool::new(0);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..5)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 5);
    }
}
