//! Per-shape block-size autotuning: search → cache → persist → restore.
//!
//! The `Meta` heuristics in [`crate::kernel`] pick one block configuration
//! per shape.  [`Tuner`] turns that single guess into a small search: it
//! asks the kernel for its candidate space ([`crate::kernel::Meta::candidates`],
//! heuristic always candidate 0), compiles each candidate through the
//! ordinary [`super::compile`] path, measures warm executions on the
//! caller's real inputs (median-of-k with early exit), and installs the
//! winner into the [`PlanCache`] so every subsequent `prepare` for that
//! (kernel, variant, shape signature) is a plain warm hit.
//!
//! Correctness gate: a candidate's warm-up output must be **bit-identical**
//! to candidate 0's output or it is skipped.  Candidate spaces already
//! never vary symbols that change accumulation order (`BLOCK_SIZE_K`, the
//! attention kv block), so tuned serving is bit-for-bit the status quo;
//! the runtime comparison is the backstop that enforces it.
//!
//! Winners persist to a versioned JSON tuning table ([`TuneTable`],
//! `NT_TUNE_TABLE`), keyed by kernel × variant × shapes and stamped with a
//! hash of the candidate space.  [`Tuner::restore`] installs matching
//! winners back into the cache *lazily* (no compile, no measurement), so a
//! restart against a table re-tunes nothing — the zero-measurement
//! guarantee the CI smoke step asserts via `nt_tune_measurements_total`.
//!
//! Corrupt, stale-version, or space-mismatched tables are ignored with a
//! warning, never a panic: the heuristic is always a safe fallback.

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::compile::{compile_with_meta, CompiledProgram, PlanCache};
use super::native::KernelDef;
use super::scheduler::GridScheduler;
use crate::json::Json;
use crate::runtime::HostTensor;

/// Timed repetitions per surviving candidate (the median is the score).
pub const TUNE_REPS: usize = 3;

/// Tuning table schema version; tables written by a different version are
/// ignored wholesale (with a warning).
pub const TUNE_TABLE_VERSION: i64 = 1;

/// `NT_TUNE` modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuneMode {
    /// No tuning anywhere: byte-for-byte the pre-tuner behaviour.
    Off,
    /// Tune each (kernel, variant, shape signature) once, at first use,
    /// skipping keys already answered by the cache or a restored table.
    FirstUse,
    /// Like `FirstUse` but every candidate gets its full measurement
    /// budget (no early exit) and restored table entries are re-searched.
    Exhaustive,
}

impl TuneMode {
    /// Parse `NT_TUNE`; unset means [`TuneMode::Off`].
    pub fn from_env() -> Result<TuneMode> {
        match std::env::var("NT_TUNE") {
            Ok(v) => TuneMode::parse(&v),
            Err(_) => Ok(TuneMode::Off),
        }
    }

    pub fn parse(v: &str) -> Result<TuneMode> {
        match v {
            "off" => Ok(TuneMode::Off),
            "first_use" => Ok(TuneMode::FirstUse),
            "exhaustive" => Ok(TuneMode::Exhaustive),
            other => bail!("NT_TUNE must be off|first_use|exhaustive, got {other:?}"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TuneMode::Off => "off",
            TuneMode::FirstUse => "first_use",
            TuneMode::Exhaustive => "exhaustive",
        }
    }
}

/// FNV-1a over a byte stream; the tuning table stamps each entry with a
/// hash of its candidate space so heuristic changes invalidate old wins.
fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Order-sensitive hash of a candidate space (the list of meta-binding
/// vectors a `Meta` policy proposes for one shape signature).
pub fn space_hash(candidates: &[Vec<(String, i64)>]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for cand in candidates {
        h = fnv1a(h, b"|");
        for (name, value) in cand {
            h = fnv1a(h, name.as_bytes());
            h = fnv1a(h, b"=");
            h = fnv1a(h, &value.to_le_bytes());
        }
    }
    h
}

/// One persisted tuning decision.
#[derive(Debug, Clone, PartialEq)]
pub struct TableEntry {
    pub kernel: String,
    pub variant: String,
    pub shapes: Vec<Vec<usize>>,
    /// [`space_hash`] of the candidate space the winner was elected from.
    pub space_hash: u64,
    /// The winning meta bindings (declaration order preserved).
    pub winner: Vec<(String, i64)>,
    /// Median warm execution time of the winner when elected.
    pub best_us: u64,
    /// Size of the candidate space searched.
    pub candidates: usize,
}

/// The on-disk tuning table: versioned JSON, written atomically
/// (temp file + rename), loaded tolerantly (any defect → warn + ignore).
#[derive(Debug, Default)]
pub struct TuneTable {
    pub entries: Vec<TableEntry>,
}

impl TuneTable {
    /// Load a table from disk.  A missing file is an empty table; a
    /// corrupt or stale-version file is an empty table **with a warning**
    /// — never an error, never a panic.
    pub fn load(path: &Path) -> TuneTable {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return TuneTable::default(),
            Err(e) => {
                eprintln!("nt-tune: ignoring tuning table {}: {e}", path.display());
                return TuneTable::default();
            }
        };
        match TuneTable::parse(&text) {
            Ok(table) => table,
            Err(e) => {
                eprintln!("nt-tune: ignoring tuning table {}: {e:#}", path.display());
                TuneTable::default()
            }
        }
    }

    /// Strict parse (the tolerant wrapper is [`TuneTable::load`]).
    pub fn parse(text: &str) -> Result<TuneTable> {
        let json = Json::parse(text).context("tuning table is not valid JSON")?;
        let version = json
            .get("version")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow!("tuning table has no version field"))?;
        if version != TUNE_TABLE_VERSION {
            bail!("tuning table version {version} != supported {TUNE_TABLE_VERSION}");
        }
        let raw_entries = json
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tuning table has no entries array"))?;
        let mut entries = Vec::new();
        for (i, raw) in raw_entries.iter().enumerate() {
            match parse_entry(raw) {
                Ok(entry) => entries.push(entry),
                Err(e) => eprintln!("nt-tune: skipping tuning-table entry {i}: {e:#}"),
            }
        }
        Ok(TuneTable { entries })
    }

    pub fn find(&self, kernel: &str, variant: &str, shapes: &[&[usize]]) -> Option<&TableEntry> {
        self.entries.iter().find(|e| {
            e.kernel == kernel
                && e.variant == variant
                && e.shapes.len() == shapes.len()
                && e.shapes.iter().zip(shapes).all(|(a, b)| a.as_slice() == *b)
        })
    }

    /// Insert or replace the entry for this (kernel, variant, shapes) key.
    pub fn upsert(&mut self, entry: TableEntry) {
        let shape_refs: Vec<&[usize]> = entry.shapes.iter().map(|s| s.as_slice()).collect();
        if let Some(pos) = self.entries.iter().position(|e| {
            e.kernel == entry.kernel
                && e.variant == entry.variant
                && e.shapes.len() == shape_refs.len()
                && e.shapes.iter().zip(&shape_refs).all(|(a, b)| a.as_slice() == *b)
        }) {
            self.entries[pos] = entry;
        } else {
            self.entries.push(entry);
        }
    }

    /// Serialize and atomically replace `path` (write temp, then rename —
    /// a concurrent reader sees either the old table or the new one).
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.serialize())
            .with_context(|| format!("writing tuning table {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming tuning table into {}", path.display()))?;
        Ok(())
    }

    pub fn serialize(&self) -> String {
        let mut out = String::from("{\"version\":");
        out.push_str(&TUNE_TABLE_VERSION.to_string());
        out.push_str(",\"entries\":[");
        for (i, e) in self.entries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&serialize_entry(e));
        }
        out.push_str("]}");
        out
    }
}

fn parse_entry(raw: &Json) -> Result<TableEntry> {
    let kernel = raw.str("kernel").context("entry kernel")?.to_string();
    let variant = raw.str("variant").context("entry variant")?.to_string();
    let shapes_raw = raw
        .get("shapes")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("entry has no shapes array"))?;
    let mut shapes = Vec::new();
    for shape in shapes_raw {
        let dims = shape.as_arr().ok_or_else(|| anyhow!("shape is not an array"))?;
        let mut out = Vec::new();
        for d in dims {
            out.push(d.as_usize().ok_or_else(|| anyhow!("shape dim is not a usize"))?);
        }
        shapes.push(out);
    }
    let hash_str = raw.str("space_hash").context("entry space_hash")?;
    let space_hash = u64::from_str_radix(hash_str, 16)
        .map_err(|_| anyhow!("space_hash {hash_str:?} is not hex"))?;
    let winner_raw = raw
        .get("winner")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("entry has no winner array"))?;
    let mut winner = Vec::new();
    for pair in winner_raw {
        let pair = pair.as_arr().ok_or_else(|| anyhow!("winner pair is not an array"))?;
        if pair.len() != 2 {
            bail!("winner pair has {} elements", pair.len());
        }
        let name = pair[0].as_str().ok_or_else(|| anyhow!("winner name is not a string"))?;
        let value = pair[1].as_i64().ok_or_else(|| anyhow!("winner value is not an i64"))?;
        winner.push((name.to_string(), value));
    }
    let best_us = raw
        .get("best_us")
        .and_then(Json::as_i64)
        .ok_or_else(|| anyhow!("entry has no best_us"))? as u64;
    let candidates = raw
        .get("candidates")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("entry has no candidates count"))?;
    Ok(TableEntry { kernel, variant, shapes, space_hash, winner, best_us, candidates })
}

fn serialize_entry(e: &TableEntry) -> String {
    let shapes: Vec<String> = e
        .shapes
        .iter()
        .map(|s| {
            let dims: Vec<String> = s.iter().map(|d| d.to_string()).collect();
            format!("[{}]", dims.join(","))
        })
        .collect();
    let winner: Vec<String> =
        e.winner.iter().map(|(name, value)| format!("[{name:?},{value}]")).collect();
    format!(
        "{{\"kernel\":{:?},\"variant\":{:?},\"shapes\":[{}],\"space_hash\":\"{:016x}\",\
         \"winner\":[{}],\"best_us\":{},\"candidates\":{}}}",
        e.kernel,
        e.variant,
        shapes.join(","),
        e.space_hash,
        winner.join(","),
        e.best_us,
        e.candidates
    )
}

/// The result of one completed search.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    /// Index of the winner in the candidate space (0 = heuristic won).
    pub winner_index: usize,
    pub winner: Vec<(String, i64)>,
    /// Size of the candidate space.
    pub candidates: usize,
    /// Candidates dropped for compile/execute failure or output mismatch.
    pub skipped: usize,
    /// Timed executions performed (the cost of the search).
    pub measurements: u64,
    /// Median warm execution time of the winner.
    pub best_us: u64,
    /// Wall-clock of the whole search.
    pub tune_us: u64,
}

/// The autotuner: owns the mode, the table, and the search serialization.
///
/// Thread-safety: concurrent first-use submissions of the same key elect
/// exactly one winner — the search runs under a lock, and the key is
/// re-checked after acquiring it, so late arrivals find the winner
/// installed and skip.
pub struct Tuner {
    mode: TuneMode,
    table_path: Option<PathBuf>,
    plans: Arc<PlanCache>,
    /// Serializes searches; the election guard for concurrent first use.
    search_lock: Mutex<()>,
    /// Keys searched in this process (`kernel`, `variant`, shape sig).
    searched: Mutex<HashSet<(String, String, String)>>,
    table: Mutex<TuneTable>,
    measurements: AtomicU64,
    tuned_plans: AtomicU64,
    tune_us_total: AtomicU64,
    restored: AtomicU64,
}

impl Tuner {
    pub fn new(mode: TuneMode, table_path: Option<PathBuf>, plans: Arc<PlanCache>) -> Tuner {
        let table = table_path.as_deref().map(TuneTable::load).unwrap_or_default();
        Tuner {
            mode,
            table_path,
            plans,
            search_lock: Mutex::new(()),
            searched: Mutex::new(HashSet::new()),
            table: Mutex::new(table),
            measurements: AtomicU64::new(0),
            tuned_plans: AtomicU64::new(0),
            tune_us_total: AtomicU64::new(0),
            restored: AtomicU64::new(0),
        }
    }

    /// Build from `NT_TUNE` / `NT_TUNE_TABLE`.
    pub fn from_env(plans: Arc<PlanCache>) -> Result<Tuner> {
        let mode = TuneMode::from_env()?;
        let table_path = std::env::var("NT_TUNE_TABLE").ok().map(PathBuf::from);
        Ok(Tuner::new(mode, table_path, plans))
    }

    pub fn mode(&self) -> TuneMode {
        self.mode
    }

    pub fn plans(&self) -> &Arc<PlanCache> {
        &self.plans
    }

    /// Timed executions performed by this tuner (0 after a pure restore —
    /// the property the restart CI gate asserts).
    pub fn measurements(&self) -> u64 {
        self.measurements.load(Ordering::Relaxed)
    }

    /// Searches that elected and installed a winner.
    pub fn tuned_plans(&self) -> u64 {
        self.tuned_plans.load(Ordering::Relaxed)
    }

    /// Total wall-clock spent searching, in microseconds.
    pub fn tune_us_total(&self) -> u64 {
        self.tune_us_total.load(Ordering::Relaxed)
    }

    /// Winners restored from the on-disk table.
    pub fn restored(&self) -> u64 {
        self.restored.load(Ordering::Relaxed)
    }

    /// Install every table winner whose kernel still exists and whose
    /// candidate space still matches the recorded hash.  Installation is
    /// lazy (`PlanCache` winner registration, no compile, no measurement);
    /// mismatches warn and fall back to searching at first use.
    pub fn restore(&self) -> usize {
        if self.mode == TuneMode::Off {
            return 0;
        }
        let table = self.table.lock().unwrap();
        let mut restored = 0usize;
        for entry in &table.entries {
            let Some(kernel) = super::lookup(&entry.kernel) else {
                eprintln!("nt-tune: table entry for unknown kernel {:?} ignored", entry.kernel);
                continue;
            };
            let shapes: Vec<&[usize]> = entry.shapes.iter().map(|s| s.as_slice()).collect();
            let candidates = match kernel.meta_candidates(&shapes) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!(
                        "nt-tune: table entry {} {}: candidate space unavailable ({e:#}), ignored",
                        entry.kernel,
                        crate::obs::shape_sig(&shapes)
                    );
                    continue;
                }
            };
            if space_hash(&candidates) != entry.space_hash || !candidates.contains(&entry.winner) {
                eprintln!(
                    "nt-tune: table entry {} {} no longer matches the candidate space, \
                     ignored (will re-tune at first use)",
                    entry.kernel,
                    crate::obs::shape_sig(&shapes)
                );
                continue;
            }
            self.plans.install_winner(
                &entry.kernel,
                &entry.variant,
                &shapes,
                entry.winner.clone(),
                None,
            );
            if self.mode == TuneMode::FirstUse {
                self.searched.lock().unwrap().insert((
                    entry.kernel.clone(),
                    entry.variant.clone(),
                    crate::obs::shape_sig(&shapes),
                ));
            }
            restored += 1;
        }
        self.restored.store(restored as u64, Ordering::Relaxed);
        restored
    }

    /// Tune (kernel, variant, input shapes) if the mode asks for it and
    /// the key has not been answered yet.  Returns `Ok(None)` when no
    /// search ran (mode off, untunable meta, already tuned/restored).
    pub fn maybe_tune(
        &self,
        kernel: &Arc<KernelDef>,
        variant: &str,
        inputs: &[HostTensor],
        scheduler: &GridScheduler,
    ) -> Result<Option<TuneOutcome>> {
        if self.mode == TuneMode::Off {
            return Ok(None);
        }
        let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
        let candidates = kernel.meta_candidates(&shapes)?;
        if candidates.len() <= 1 {
            return Ok(None);
        }
        let key = (kernel.name.clone(), variant.to_string(), crate::obs::shape_sig(&shapes));
        if self.answered(&key, variant, &shapes, kernel) {
            return Ok(None);
        }
        let _search = self.search_lock.lock().unwrap();
        // Re-check under the lock: concurrent first-use submissions of
        // the same key elect exactly one winner.
        if self.answered(&key, variant, &shapes, kernel) {
            return Ok(None);
        }
        let outcome = self.tune_with_candidates(kernel, variant, inputs, &candidates, scheduler)?;
        self.searched.lock().unwrap().insert(key);
        Ok(Some(outcome))
    }

    fn answered(
        &self,
        key: &(String, String, String),
        variant: &str,
        shapes: &[&[usize]],
        kernel: &Arc<KernelDef>,
    ) -> bool {
        if self.searched.lock().unwrap().contains(key) {
            return true;
        }
        self.mode == TuneMode::FirstUse
            && self.plans.winner(&kernel.name, variant, shapes).is_some()
    }

    /// Run one search over an explicit candidate space (the fault-injection
    /// entry point: tests feed bogus candidates here).  Candidate 0 must
    /// compile and execute — it is the guaranteed heuristic fallback and
    /// the bit-identity reference; any later candidate that fails to
    /// compile, fails to execute, or produces a different output is
    /// skipped, not fatal.
    pub fn tune_with_candidates(
        &self,
        kernel: &Arc<KernelDef>,
        variant: &str,
        inputs: &[HostTensor],
        candidates: &[Vec<(String, i64)>],
        scheduler: &GridScheduler,
    ) -> Result<TuneOutcome> {
        let t_start = Instant::now();
        let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
        let mut best: Option<(usize, Arc<CompiledProgram>, u64)> = None;
        let mut reference: Option<Vec<HostTensor>> = None;
        let mut measurements = 0u64;
        let mut skipped = 0usize;
        for (idx, cand) in candidates.iter().enumerate() {
            let compiled = match compile_with_meta(kernel, &shapes, cand) {
                Ok(c) => Arc::new(c),
                Err(e) if idx == 0 => {
                    return Err(e).with_context(|| {
                        format!("tuning {}: heuristic candidate failed to compile", kernel.name)
                    });
                }
                Err(_) => {
                    skipped += 1;
                    continue;
                }
            };
            // Warm-up, doubling as the bit-identity gate against candidate 0.
            let output = match compiled.execute(inputs, scheduler) {
                Ok(o) => o,
                Err(e) if idx == 0 => {
                    return Err(e).with_context(|| {
                        format!("tuning {}: heuristic candidate failed to execute", kernel.name)
                    });
                }
                Err(_) => {
                    skipped += 1;
                    continue;
                }
            };
            match &reference {
                None => reference = Some(output),
                Some(r) => {
                    if &output != r {
                        skipped += 1;
                        continue;
                    }
                }
            }
            let mut times = Vec::with_capacity(TUNE_REPS);
            let mut lost = false;
            let mut failed = false;
            for rep in 0..TUNE_REPS {
                let t0 = Instant::now();
                if compiled.execute(inputs, scheduler).is_err() {
                    failed = true;
                    break;
                }
                let us = t0.elapsed().as_micros() as u64;
                measurements += 1;
                times.push(us);
                // Early exit: a candidate whose first rep is already well
                // behind the incumbent's median cannot win the median.
                if self.mode != TuneMode::Exhaustive && rep == 0 {
                    if let Some((_, _, best_us)) = &best {
                        if us > best_us.saturating_mul(2) {
                            lost = true;
                            break;
                        }
                    }
                }
            }
            if failed {
                if idx == 0 {
                    bail!("tuning {}: heuristic candidate failed mid-measurement", kernel.name);
                }
                skipped += 1;
                continue;
            }
            if lost {
                continue;
            }
            times.sort_unstable();
            let median = times[times.len() / 2];
            let better = match &best {
                None => true,
                Some((_, _, incumbent)) => median < *incumbent,
            };
            if better {
                best = Some((idx, compiled, median));
            }
        }
        let (winner_index, program, best_us) = best.ok_or_else(|| {
            anyhow!("tuning {}: no viable candidate among {}", kernel.name, candidates.len())
        })?;
        let winner = candidates[winner_index].clone();
        self.plans.install_winner(&kernel.name, variant, &shapes, winner.clone(), Some(program));
        self.tuned_plans.fetch_add(1, Ordering::Relaxed);
        self.measurements.fetch_add(measurements, Ordering::Relaxed);
        let tune_us = t_start.elapsed().as_micros() as u64;
        self.tune_us_total.fetch_add(tune_us, Ordering::Relaxed);
        if let Some(path) = &self.table_path {
            let mut table = self.table.lock().unwrap();
            table.upsert(TableEntry {
                kernel: kernel.name.clone(),
                variant: variant.to_string(),
                shapes: shapes.iter().map(|s| s.to_vec()).collect(),
                space_hash: space_hash(candidates),
                winner: winner.clone(),
                best_us,
                candidates: candidates.len(),
            });
            if let Err(e) = table.save(path) {
                eprintln!("nt-tune: failed to persist tuning table: {e:#}");
            }
        }
        Ok(TuneOutcome {
            winner_index,
            winner,
            candidates: candidates.len(),
            skipped,
            measurements,
            best_us,
            tune_us,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry() -> TableEntry {
        TableEntry {
            kernel: "mm".to_string(),
            variant: "nt".to_string(),
            shapes: vec![vec![70, 50], vec![50, 90]],
            space_hash: 0x1234_5678_9abc_def0,
            winner: vec![
                ("BLOCK_SIZE_M".to_string(), 64),
                ("BLOCK_SIZE_N".to_string(), 32),
                ("BLOCK_SIZE_K".to_string(), 50),
            ],
            best_us: 123,
            candidates: 9,
        }
    }

    #[test]
    fn table_roundtrip() {
        let mut table = TuneTable::default();
        table.upsert(entry());
        let parsed = TuneTable::parse(&table.serialize()).unwrap();
        assert_eq!(parsed.entries, table.entries);
        let found = parsed.find("mm", "nt", &[&[70, 50], &[50, 90]]).unwrap();
        assert_eq!(found.winner[0].1, 64);
        assert!(parsed.find("mm", "nt", &[&[70, 51], &[51, 90]]).is_none());
    }

    #[test]
    fn table_upsert_replaces() {
        let mut table = TuneTable::default();
        table.upsert(entry());
        let mut updated = entry();
        updated.best_us = 77;
        table.upsert(updated);
        assert_eq!(table.entries.len(), 1);
        assert_eq!(table.entries[0].best_us, 77);
    }

    #[test]
    fn corrupt_table_is_ignored() {
        assert!(TuneTable::parse("{not json").is_err());
        assert!(TuneTable::parse("{\"entries\":[]}").is_err());
        let stale = format!("{{\"version\":{},\"entries\":[]}}", TUNE_TABLE_VERSION + 1);
        assert!(TuneTable::parse(&stale).is_err());
    }

    #[test]
    fn malformed_entries_are_skipped_not_fatal() {
        let text = format!(
            "{{\"version\":{TUNE_TABLE_VERSION},\"entries\":[{{\"kernel\":\"mm\"}},{}]}}",
            serialize_entry(&entry())
        );
        let table = TuneTable::parse(&text).unwrap();
        assert_eq!(table.entries.len(), 1);
    }

    #[test]
    fn space_hash_is_order_and_value_sensitive() {
        let a = vec![vec![("BLOCK_SIZE".to_string(), 64)]];
        let b = vec![vec![("BLOCK_SIZE".to_string(), 128)]];
        let c = vec![
            vec![("BLOCK_SIZE".to_string(), 64)],
            vec![("BLOCK_SIZE".to_string(), 128)],
        ];
        assert_eq!(space_hash(&a), space_hash(&a));
        assert_ne!(space_hash(&a), space_hash(&b));
        assert_ne!(space_hash(&a), space_hash(&c));
    }

    #[test]
    fn tune_mode_parses() {
        assert_eq!(TuneMode::parse("off").unwrap(), TuneMode::Off);
        assert_eq!(TuneMode::parse("first_use").unwrap(), TuneMode::FirstUse);
        assert_eq!(TuneMode::parse("exhaustive").unwrap(), TuneMode::Exhaustive);
        assert!(TuneMode::parse("banana").is_err());
    }
}
