//! Straightforward reference implementations of the native-backend
//! kernels (the Rust analogue of `python/compile/kernels/ref.py`).
//!
//! These are the correctness oracles the native tile programs are
//! cross-checked against in `cargo test`: simple loops, f64 accumulation
//! for reductions and matrix products, no tiling.

use anyhow::{bail, Result};

use crate::runtime::HostTensor;

pub fn add(a: &HostTensor, b: &HostTensor) -> Result<HostTensor> {
    let (x, y) = (a.as_f32()?, b.as_f32()?);
    if a.shape != b.shape {
        bail!("add shape mismatch: {:?} vs {:?}", a.shape, b.shape);
    }
    HostTensor::f32(a.shape.clone(), x.iter().zip(y).map(|(p, q)| p + q).collect())
}

pub fn silu(a: &HostTensor) -> Result<HostTensor> {
    let x = a.as_f32()?;
    HostTensor::f32(
        a.shape.clone(),
        x.iter().map(|&v| v * (1.0 / (1.0 + (-v).exp()))).collect(),
    )
}

/// tanh-approximated GELU (the same `x * sigmoid(2*sqrt(2/pi)*(x +
/// 0.044715*x^3))` identity the tile program computes, evaluated in f64).
pub fn gelu(a: &HostTensor) -> Result<HostTensor> {
    let x = a.as_f32()?;
    let c = 2.0f64 * (2.0f64 / std::f64::consts::PI).sqrt();
    HostTensor::f32(
        a.shape.clone(),
        x.iter()
            .map(|&v| {
                let v = v as f64;
                let arg = c * (v + 0.044715 * v * v * v);
                (v / (1.0 + (-arg).exp())) as f32
            })
            .collect(),
    )
}

pub fn softmax(a: &HostTensor) -> Result<HostTensor> {
    let x = a.as_f32()?;
    if a.shape.len() != 2 {
        bail!("softmax expects a 2-D tensor, got {:?}", a.shape);
    }
    let (rows, cols) = (a.shape[0], a.shape[1]);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f64;
        for &v in row {
            denom += ((v - max) as f64).exp();
        }
        for (o, &v) in out[r * cols..(r + 1) * cols].iter_mut().zip(row) {
            *o = (((v - max) as f64).exp() / denom) as f32;
        }
    }
    HostTensor::f32(a.shape.clone(), out)
}

pub fn rms_norm(a: &HostTensor) -> Result<HostTensor> {
    const EPS: f64 = 1e-6;
    let x = a.as_f32()?;
    if a.shape.len() != 2 {
        bail!("rms_norm expects a 2-D tensor, got {:?}", a.shape);
    }
    let (rows, cols) = (a.shape[0], a.shape[1]);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let ms = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / cols as f64;
        let scale = 1.0 / (ms + EPS).sqrt();
        for (o, &v) in out[r * cols..(r + 1) * cols].iter_mut().zip(row) {
            *o = (v as f64 * scale) as f32;
        }
    }
    HostTensor::f32(a.shape.clone(), out)
}

/// Row-wise layer normalization without affine weight/bias
/// (`(x - mean) * rsqrt(var + 1e-6)`, eps consistent with [`rms_norm`]).
pub fn layer_norm(a: &HostTensor) -> Result<HostTensor> {
    const EPS: f64 = 1e-6;
    let x = a.as_f32()?;
    if a.shape.len() != 2 {
        bail!("layer_norm expects a 2-D tensor, got {:?}", a.shape);
    }
    let (rows, cols) = (a.shape[0], a.shape[1]);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let mean = row.iter().map(|&v| v as f64).sum::<f64>() / cols as f64;
        let var = row.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / cols as f64;
        let scale = 1.0 / (var + EPS).sqrt();
        for (o, &v) in out[r * cols..(r + 1) * cols].iter_mut().zip(row) {
            *o = ((v as f64 - mean) * scale) as f32;
        }
    }
    HostTensor::f32(a.shape.clone(), out)
}

pub fn mm(a: &HostTensor, b: &HostTensor) -> Result<HostTensor> {
    let (x, y) = (a.as_f32()?, b.as_f32()?);
    if a.shape.len() != 2 || b.shape.len() != 2 || a.shape[1] != b.shape[0] {
        bail!("mm shape mismatch: {:?} x {:?}", a.shape, b.shape);
    }
    let (m, k, n) = (a.shape[0], a.shape[1], b.shape[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                acc += x[i * k + p] as f64 * y[p * n + j] as f64;
            }
            out[i * n + j] = acc as f32;
        }
    }
    HostTensor::f32(vec![m, n], out)
}

/// `addmm(bias, mat1, mat2) = bias + mat1 @ mat2` (torch.addmm with
/// alpha = beta = 1), the bias broadcast over rows when it is `[n]` or
/// `[1, n]`.
pub fn addmm(bias: &HostTensor, a: &HostTensor, b: &HostTensor) -> Result<HostTensor> {
    let prod = mm(a, b)?;
    let (m, n) = (prod.shape[0], prod.shape[1]);
    let row_bias = match bias.shape.as_slice() {
        [len] if *len == n => true,
        [1, len] if *len == n => true,
        [rows, len] if *rows == m && *len == n => false,
        other => bail!("addmm bias {other:?} does not broadcast to [{m}, {n}]"),
    };
    let (p, bv) = (prod.as_f32()?, bias.as_f32()?);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let add = if row_bias { bv[j] } else { bv[i * n + j] };
            out[i * n + j] = p[i * n + j] + add;
        }
    }
    HostTensor::f32(vec![m, n], out)
}

pub fn bmm(a: &HostTensor, b: &HostTensor) -> Result<HostTensor> {
    if a.shape.len() != 3
        || b.shape.len() != 3
        || a.shape[0] != b.shape[0]
        || a.shape[2] != b.shape[1]
    {
        bail!("bmm shape mismatch: {:?} x {:?}", a.shape, b.shape);
    }
    let (batch, m, k) = (a.shape[0], a.shape[1], a.shape[2]);
    let n = b.shape[2];
    let (x, y) = (a.as_f32()?, b.as_f32()?);
    let mut out = vec![0.0f32; batch * m * n];
    for bi in 0..batch {
        let xa = &x[bi * m * k..(bi + 1) * m * k];
        let yb = &y[bi * k * n..(bi + 1) * k * n];
        let ob = &mut out[bi * m * n..(bi + 1) * m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += xa[i * k + p] as f64 * yb[p * n + j] as f64;
                }
                ob[i * n + j] = acc as f32;
            }
        }
    }
    HostTensor::f32(vec![batch, m, n], out)
}

/// Rotary position embedding, half-rotation (Llama) convention.
/// `input` is `[B, S, H, D]`; `cos`/`sin` are `[S, D/2]` tables applied
/// per position, broadcast over batch and heads (f64 arithmetic).
pub fn rope(input: &HostTensor, cos: &HostTensor, sin: &HostTensor) -> Result<HostTensor> {
    let x = input.as_f32()?;
    if input.shape.len() != 4 {
        bail!("rope expects a 4-D input, got {:?}", input.shape);
    }
    let (b, s, h, d) = (input.shape[0], input.shape[1], input.shape[2], input.shape[3]);
    if d % 2 != 0 {
        bail!("rope needs an even head dimension, got {d}");
    }
    let half = d / 2;
    let want = vec![s, half];
    if cos.shape != want || sin.shape != want {
        bail!(
            "rope cos/sin tables must be {want:?}, got {:?} and {:?}",
            cos.shape,
            sin.shape
        );
    }
    let (c, sn) = (cos.as_f32()?, sin.as_f32()?);
    let mut out = vec![0.0f32; b * s * h * d];
    for bi in 0..b {
        for si in 0..s {
            for hi in 0..h {
                let row = ((bi * s + si) * h + hi) * d;
                for i in 0..half {
                    let x1 = x[row + i] as f64;
                    let x2 = x[row + half + i] as f64;
                    let cv = c[si * half + i] as f64;
                    let sv = sn[si * half + i] as f64;
                    out[row + i] = (x1 * cv - x2 * sv) as f32;
                    out[row + half + i] = (x2 * cv + x1 * sv) as f32;
                }
            }
        }
    }
    HostTensor::f32(input.shape.clone(), out)
}

/// Scaled dot-product attention, `softmax(Q K^T / sqrt(d)) V` over
/// `[b, h, s, d]` tensors, computed naively in f64 (two-pass row
/// softmax) — the oracle the flash-style native sdpa is checked against.
pub fn sdpa(query: &HostTensor, key: &HostTensor, value: &HostTensor) -> Result<HostTensor> {
    sdpa_with_bias(query, key, value, None)
}

/// [`sdpa`] with an `[s, s]` additive score bias applied before the
/// softmax (`-1e30` entries express causal/attention masks), broadcast
/// over batch and heads.
pub fn sdpa_bias(
    query: &HostTensor,
    key: &HostTensor,
    value: &HostTensor,
    bias: &HostTensor,
) -> Result<HostTensor> {
    sdpa_with_bias(query, key, value, Some(bias))
}

fn sdpa_with_bias(
    query: &HostTensor,
    key: &HostTensor,
    value: &HostTensor,
    bias: Option<&HostTensor>,
) -> Result<HostTensor> {
    if query.shape.len() != 4 || query.shape != key.shape || query.shape != value.shape {
        bail!(
            "sdpa expects equal-shape [b, h, s, d] query/key/value, got {:?} / {:?} / {:?}",
            query.shape,
            key.shape,
            value.shape
        );
    }
    let (b, h, s, d) = (query.shape[0], query.shape[1], query.shape[2], query.shape[3]);
    let bias_data = match bias {
        Some(t) => {
            if t.shape != [s, s] {
                bail!("sdpa bias must be [{s}, {s}], got {:?}", t.shape);
            }
            Some(t.as_f32()?)
        }
        None => None,
    };
    let (q, k, v) = (query.as_f32()?, key.as_f32()?, value.as_f32()?);
    let scale = 1.0 / (d as f64).sqrt();
    let mut out = vec![0.0f32; b * h * s * d];
    let mut scores = vec![0.0f64; s];
    let mut acc = vec![0.0f64; d];
    for bh in 0..b * h {
        let base = bh * s * d;
        for i in 0..s {
            let qrow = &q[base + i * d..base + (i + 1) * d];
            for j in 0..s {
                let krow = &k[base + j * d..base + (j + 1) * d];
                let mut dot = 0.0f64;
                for (qa, kb) in qrow.iter().zip(krow) {
                    dot += *qa as f64 * *kb as f64;
                }
                scores[j] = dot * scale;
                if let Some(bias) = bias_data {
                    scores[j] += bias[i * s + j] as f64;
                }
            }
            let max = scores.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            let mut denom = 0.0f64;
            for sc in scores.iter_mut() {
                *sc = (*sc - max).exp();
                denom += *sc;
            }
            acc.fill(0.0);
            for (j, &p) in scores.iter().enumerate() {
                let w = p / denom;
                let vrow = &v[base + j * d..base + (j + 1) * d];
                for (a, &vv) in acc.iter_mut().zip(vrow) {
                    *a += w * vv as f64;
                }
            }
            let orow = &mut out[base + i * d..base + (i + 1) * d];
            for (o, &a) in orow.iter_mut().zip(&acc) {
                *o = a as f32;
            }
        }
    }
    HostTensor::f32(query.shape.clone(), out)
}

/// Kernels [`run`] can dispatch — the single source of truth the router
/// and registry consult before admitting a `ref`-variant fallback.
pub const SUPPORTED: &[&str] = &[
    "add",
    "silu",
    "gelu",
    "softmax",
    "rms_norm",
    "layer_norm",
    "mm",
    "bmm",
    "addmm",
    "rope",
    "sdpa",
    "sdpa_bias",
];

/// True if a reference oracle exists for this kernel.
pub fn supports(name: &str) -> bool {
    SUPPORTED.contains(&name)
}

/// Dispatch by kernel name (the oracle the native backend is checked
/// against, and the `ref` variant of the native serving path).
pub fn run(name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let need = |n: usize| -> Result<()> {
        if inputs.len() != n {
            bail!("reference {name} expects {n} inputs, got {}", inputs.len());
        }
        Ok(())
    };
    let out = match name {
        "add" => {
            need(2)?;
            add(&inputs[0], &inputs[1])?
        }
        "silu" => {
            need(1)?;
            silu(&inputs[0])?
        }
        "gelu" => {
            need(1)?;
            gelu(&inputs[0])?
        }
        "softmax" => {
            need(1)?;
            softmax(&inputs[0])?
        }
        "rms_norm" => {
            need(1)?;
            rms_norm(&inputs[0])?
        }
        "layer_norm" => {
            need(1)?;
            layer_norm(&inputs[0])?
        }
        "mm" => {
            need(2)?;
            mm(&inputs[0], &inputs[1])?
        }
        "bmm" => {
            need(2)?;
            bmm(&inputs[0], &inputs[1])?
        }
        "addmm" => {
            need(3)?;
            addmm(&inputs[0], &inputs[1], &inputs[2])?
        }
        "rope" => {
            need(3)?;
            rope(&inputs[0], &inputs[1], &inputs[2])?
        }
        "sdpa" => {
            need(3)?;
            sdpa(&inputs[0], &inputs[1], &inputs[2])?
        }
        "sdpa_bias" => {
            need(4)?;
            sdpa_bias(&inputs[0], &inputs[1], &inputs[2], &inputs[3])?
        }
        other => bail!("no reference implementation for kernel {other:?}"),
    };
    Ok(vec![out])
}
