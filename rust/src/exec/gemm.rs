//! Blocked, cache-aware f32 GEMM microkernel (`C += A x B`).
//!
//! The naive i-k-j loop that [`super::tile::Tile::dot`] shipped with is
//! memory-bound the moment operands leave L2: every element of `B` is
//! re-streamed `M` times.  This module applies the classic three-level
//! blocking scheme (Goto/BLIS; see also ML-Triton's lowering levels in
//! PAPERS.md):
//!
//! * **KC x NC panels of `B`** are packed into a contiguous buffer laid
//!   out as NR-column strips, so the inner kernel reads it sequentially;
//! * **MC x KC panels of `A`** are packed as MR-row strips the same way;
//! * an **MR x NR register tile** is accumulated over the KC depth by a
//!   fully unrolled FMA kernel written so the autovectorizer emits SIMD
//!   (`std`-only: no intrinsics, no new dependencies).
//!
//! Edge strips are zero-padded during packing, so the microkernel is
//! always full-size and only the write-back masks partial tiles.  Inputs
//! address arbitrary strided windows (`offset + i*row_stride +
//! j*col_stride` over a flat buffer), which is what lets
//! [`super::ir::Instr::DotAcc`] feed source tensors straight into the
//! kernel without materializing tiles first.
//!
//! Shapes too small to amortize packing take [`small_gemm`], a strided
//! i-k-j loop — tiny tiles (the 32-wide legacy blocks) pay no packing
//! overhead at all.  The path is chosen from the *full* problem shape
//! before any row-splitting, so [`gemm_rows_parallel`] produces
//! bit-identical results for every thread count.

use std::cell::RefCell;

/// Rows of the register tile.
pub const MR: usize = 8;
/// Columns of the register tile.
pub const NR: usize = 8;
/// Rows of a packed `A` panel (multiple of `MR`).
const MC: usize = 64;
/// Columns of a packed `B` panel (multiple of `NR`).
const NC: usize = 128;
/// Shared depth of one packed panel pair.
const KC: usize = 256;
/// At or below this many multiply-adds packing costs more than it saves.
pub const SMALL_MADDS: usize = 64 * 64 * 64;
/// Minimum multiply-adds before intra-tile row-splitting is worth a spawn.
pub const INTRA_PAR_MIN_MADDS: usize = 1 << 20;

thread_local! {
    static PACK_A: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    static PACK_B: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// `base + delta` in flat-buffer coordinates (strides may be negative).
#[inline(always)]
fn at(base: usize, delta: isize) -> usize {
    (base as isize + delta) as usize
}

/// `C[m x n] += A[m x k] x B[k x n]` over strided windows.
///
/// `A` is addressed as `a[a_off + i*a_rs + p*a_cs]`, `B` as
/// `b[b_off + p*b_rs + j*b_cs]`, and `C` as `c[c_off + i*c_rs + j]`
/// (`C` columns are always unit-stride — both `Tile` buffers and
/// accumulator registers are row-major contiguous).  Every addressed
/// element must be in range; callers guarantee that via
/// `ParamView::dense_window` or by passing contiguous tiles.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    a_off: usize,
    a_rs: isize,
    a_cs: isize,
    b: &[f32],
    b_off: usize,
    b_rs: isize,
    b_cs: isize,
    c: &mut [f32],
    c_off: usize,
    c_rs: usize,
) {
    let small = m * n * k <= SMALL_MADDS;
    gemm_path(small, m, n, k, a, a_off, a_rs, a_cs, b, b_off, b_rs, b_cs, c, c_off, c_rs);
}

/// [`gemm`] with the small-vs-blocked decision already made — row-split
/// callers pin the path from the full shape so chunking never changes
/// summation order.
#[allow(clippy::too_many_arguments)]
fn gemm_path(
    small: bool,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    a_off: usize,
    a_rs: isize,
    a_cs: isize,
    b: &[f32],
    b_off: usize,
    b_rs: isize,
    b_cs: isize,
    c: &mut [f32],
    c_off: usize,
    c_rs: usize,
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if small {
        small_gemm(m, n, k, a, a_off, a_rs, a_cs, b, b_off, b_rs, b_cs, c, c_off, c_rs);
        return;
    }
    PACK_A.with(|pa| {
        PACK_B.with(|pb| {
            let (mut pa, mut pb) = (pa.borrow_mut(), pb.borrow_mut());
            for jc in (0..n).step_by(NC) {
                let nc = NC.min(n - jc);
                for pc in (0..k).step_by(KC) {
                    let kc = KC.min(k - pc);
                    pack_b(
                        kc,
                        nc,
                        b,
                        at(b_off, pc as isize * b_rs + jc as isize * b_cs),
                        b_rs,
                        b_cs,
                        &mut pb,
                    );
                    for ic in (0..m).step_by(MC) {
                        let mc = MC.min(m - ic);
                        pack_a(
                            mc,
                            kc,
                            a,
                            at(a_off, ic as isize * a_rs + pc as isize * a_cs),
                            a_rs,
                            a_cs,
                            &mut pa,
                        );
                        macro_kernel(mc, nc, kc, &pa, &pb, c, c_off + ic * c_rs + jc, c_rs);
                    }
                }
            }
        })
    });
}

/// `C += A x B` with `C` exactly `m * n` contiguous row-major elements,
/// rows split into up to `threads` chunks dispatched to the persistent
/// worker pool (`super::pool` — no per-call thread spawns).  This is the
/// intra-tile parallelism path the grid scheduler enables when the grid
/// is too small to occupy the pool (a big single-tile GEMM).  Results are
/// bit-identical for every thread count: the small-vs-blocked choice is
/// pinned from the full shape, and each `C` element's accumulation order
/// is independent of the row split.
#[allow(clippy::too_many_arguments)]
pub fn gemm_rows_parallel(
    threads: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    a_off: usize,
    a_rs: isize,
    a_cs: isize,
    b: &[f32],
    b_off: usize,
    b_rs: isize,
    b_cs: isize,
    c: &mut [f32],
) {
    debug_assert_eq!(c.len(), m * n, "gemm_rows_parallel C must be exactly m*n");
    let small = m * n * k <= SMALL_MADDS;
    let t = threads.min(m.div_ceil(MR)).max(1);
    if t == 1 {
        gemm_path(small, m, n, k, a, a_off, a_rs, a_cs, b, b_off, b_rs, b_cs, c, 0, n);
        return;
    }
    let rows_per = m.div_ceil(t);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(t);
    let mut rest = c;
    let mut row0 = 0usize;
    while row0 < m {
        let rows = rows_per.min(m - row0);
        let (head, tail) = rest.split_at_mut(rows * n);
        rest = tail;
        let a_base = at(a_off, row0 as isize * a_rs);
        tasks.push(Box::new(move || {
            gemm_path(small, rows, n, k, a, a_base, a_rs, a_cs, b, b_off, b_rs, b_cs, head, 0, n);
        }));
        row0 += rows;
    }
    super::pool::global().run_scoped(tasks);
}

/// Strided i-k-j loop for shapes below the packing threshold.  The inner
/// loop walks `B` and `C` rows contiguously when `b_cs == 1` (the common
/// tile layout), which the autovectorizer turns into an AXPY.
#[allow(clippy::too_many_arguments)]
fn small_gemm(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    a_off: usize,
    a_rs: isize,
    a_cs: isize,
    b: &[f32],
    b_off: usize,
    b_rs: isize,
    b_cs: isize,
    c: &mut [f32],
    c_off: usize,
    c_rs: usize,
) {
    for i in 0..m {
        let a_row = at(a_off, i as isize * a_rs);
        let c_row = c_off + i * c_rs;
        if b_cs == 1 {
            let crow = &mut c[c_row..c_row + n];
            for p in 0..k {
                let av = a[at(a_row, p as isize * a_cs)];
                let b_row = at(b_off, p as isize * b_rs);
                let brow = &b[b_row..b_row + n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        } else {
            for p in 0..k {
                let av = a[at(a_row, p as isize * a_cs)];
                let b_row = at(b_off, p as isize * b_rs);
                for j in 0..n {
                    c[c_row + j] += av * b[at(b_row, j as isize * b_cs)];
                }
            }
        }
    }
}

/// Pack an `mc x kc` window of `A` into MR-row strips, k-major within
/// each strip (`out[strip][p*MR + i]`), zero-padding the ragged last
/// strip so the microkernel never branches on `m`.
fn pack_a(mc: usize, kc: usize, a: &[f32], base: usize, rs: isize, cs: isize, out: &mut Vec<f32>) {
    let strips = mc.div_ceil(MR);
    out.clear();
    out.resize(strips * kc * MR, 0.0);
    for si in 0..strips {
        let rows = MR.min(mc - si * MR);
        let dst = &mut out[si * kc * MR..(si + 1) * kc * MR];
        for p in 0..kc {
            let col = at(base, p as isize * cs);
            for i in 0..rows {
                dst[p * MR + i] = a[at(col, (si * MR + i) as isize * rs)];
            }
        }
    }
}

/// Pack a `kc x nc` window of `B` into NR-column strips, k-major within
/// each strip (`out[strip][p*NR + j]`), zero-padding the ragged last
/// strip.
fn pack_b(kc: usize, nc: usize, b: &[f32], base: usize, rs: isize, cs: isize, out: &mut Vec<f32>) {
    let strips = nc.div_ceil(NR);
    out.clear();
    out.resize(strips * kc * NR, 0.0);
    for sj in 0..strips {
        let cols = NR.min(nc - sj * NR);
        let dst = &mut out[sj * kc * NR..(sj + 1) * kc * NR];
        for p in 0..kc {
            let row = at(base, p as isize * rs);
            for j in 0..cols {
                dst[p * NR + j] = b[at(row, (sj * NR + j) as isize * cs)];
            }
        }
    }
}

/// Multiply packed panels into `C`: one MR x NR register tile per strip
/// pair, accumulated over the full `kc` depth, then masked-added into the
/// (possibly partial) destination tile.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    mc: usize,
    nc: usize,
    kc: usize,
    pa: &[f32],
    pb: &[f32],
    c: &mut [f32],
    c_base: usize,
    c_rs: usize,
) {
    let m_strips = mc.div_ceil(MR);
    let n_strips = nc.div_ceil(NR);
    let mut acc = [0.0f32; MR * NR];
    for jr in 0..n_strips {
        let cols = NR.min(nc - jr * NR);
        let bpanel = &pb[jr * kc * NR..(jr + 1) * kc * NR];
        for ir in 0..m_strips {
            let rows = MR.min(mc - ir * MR);
            let apanel = &pa[ir * kc * MR..(ir + 1) * kc * MR];
            acc.fill(0.0);
            microkernel(apanel, bpanel, &mut acc);
            for i in 0..rows {
                let row = c_base + (ir * MR + i) * c_rs + jr * NR;
                let crow = &mut c[row..row + cols];
                for (cv, &av) in crow.iter_mut().zip(&acc[i * NR..i * NR + cols]) {
                    *cv += av;
                }
            }
        }
    }
}

/// The register tile: `acc[MR x NR] += strip_a^T x strip_b` over the
/// full packed depth (`strip_a` is `kc x MR`, `strip_b` is `kc x NR`,
/// both k-major).  `chunks_exact` gives the compiler constant-width
/// slices with no per-iteration bounds checks, so the body unrolls into
/// a SIMD FMA chain with `acc` held in vector registers.
#[inline(always)]
fn microkernel(pa: &[f32], pb: &[f32], acc: &mut [f32; MR * NR]) {
    for (a, b) in pa.chunks_exact(MR).zip(pb.chunks_exact(NR)) {
        for (&ai, row) in a.iter().zip(acc.chunks_exact_mut(NR)) {
            for (r, &bv) in row.iter_mut().zip(b) {
                *r += ai * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::SplitMix64;

    /// f64-accumulating oracle over the same strided addressing.
    #[allow(clippy::too_many_arguments)]
    fn oracle(
        m: usize,
        n: usize,
        k: usize,
        a: &[f32],
        a_off: usize,
        a_rs: isize,
        a_cs: isize,
        b: &[f32],
        b_off: usize,
        b_rs: isize,
        b_cs: isize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    let av = a[at(a_off, i as isize * a_rs + p as isize * a_cs)];
                    let bv = b[at(b_off, p as isize * b_rs + j as isize * b_cs)];
                    acc += av as f64 * bv as f64;
                }
                out[i * n + j] = acc as f32;
            }
        }
        out
    }

    fn randv(n: usize, rng: &mut SplitMix64) -> Vec<f32> {
        rng.normal_vec(n)
    }

    fn max_abs_diff(x: &[f32], y: &[f32]) -> f32 {
        x.iter().zip(y).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn blocked_matches_oracle_on_contiguous_shapes() {
        let mut rng = SplitMix64::new(41);
        // odd / prime / ragged-strip shapes on both sides of the small
        // threshold, including ones that exercise every packing edge
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 7, 5),
            (8, 8, 8),
            (9, 17, 11),
            (31, 127, 63),
            (65, 70, 66),
            (127, 129, 65),
            (130, 300, 70),
        ] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let want = oracle(m, n, k, &a, 0, k as isize, 1, &b, 0, n as isize, 1);
            let mut got = vec![0.0f32; m * n];
            gemm(m, n, k, &a, 0, k as isize, 1, &b, 0, n as isize, 1, &mut got, 0, n);
            let diff = max_abs_diff(&got, &want);
            assert!(diff <= 1e-3, "({m},{k},{n}): max|diff| = {diff}");
        }
    }

    #[test]
    fn strided_windows_match_oracle() {
        let mut rng = SplitMix64::new(42);
        // a window of a larger row-major matrix, and a transposed B
        let (big_r, big_c) = (40usize, 50usize);
        let buf_a = randv(big_r * big_c, &mut rng);
        let buf_b = randv(big_r * big_c, &mut rng);
        let (m, k, n) = (17usize, 23usize, 19usize);
        // A window starting at (3, 4); B read transposed from (1, 2)
        let a_off = 3 * big_c + 4;
        let b_off = big_c + 2;
        let want = oracle(
            m, n, k, &buf_a, a_off, big_c as isize, 1, &buf_b, b_off, 1, big_c as isize,
        );
        let mut got = vec![0.0f32; m * n];
        gemm(
            m, n, k, &buf_a, a_off, big_c as isize, 1, &buf_b, b_off, 1, big_c as isize, &mut got,
            0, n,
        );
        let diff = max_abs_diff(&got, &want);
        assert!(diff <= 1e-3, "strided: max|diff| = {diff}");
    }

    #[test]
    fn accumulates_instead_of_overwriting() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [1.0f32, 0.0, 0.0, 1.0];
        let mut c = [10.0f32; 4];
        gemm(2, 2, 2, &a, 0, 2, 1, &b, 0, 2, 1, &mut c, 0, 2);
        assert_eq!(c, [11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn row_parallel_is_bit_identical_to_serial() {
        let mut rng = SplitMix64::new(43);
        let (m, k, n) = (70usize, 90usize, 50usize);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut serial = vec![0.0f32; m * n];
        gemm_rows_parallel(1, m, n, k, &a, 0, k as isize, 1, &b, 0, n as isize, 1, &mut serial);
        for threads in [2, 3, 8] {
            let mut par = vec![0.0f32; m * n];
            gemm_rows_parallel(
                threads, m, n, k, &a, 0, k as isize, 1, &b, 0, n as isize, 1, &mut par,
            );
            assert_eq!(serial, par, "{threads}-way row split changed bits");
        }
    }

    #[test]
    fn zero_dims_are_noops() {
        let a = [1.0f32; 4];
        let b = [1.0f32; 4];
        let mut c = [7.0f32; 4];
        gemm(0, 2, 2, &a, 0, 2, 1, &b, 0, 2, 1, &mut c, 0, 2);
        gemm(2, 2, 0, &a, 0, 0, 1, &b, 0, 2, 1, &mut c, 0, 2);
        assert_eq!(c, [7.0f32; 4]);
    }
}
