//! The compile stage of the native backend's **compile → cache → execute**
//! pipeline.
//!
//! The paper's code generator compiles an arrangement once and launches it
//! many times; the original native backend instead re-specialized the
//! arrangement, re-lowered every `ParamView` (affine probing included) and
//! re-derived the tiling on **every** request.  This module makes the
//! compiled artifact explicit:
//!
//! * [`compile`] turns `(kernel, input shapes)` into a
//!   [`CompiledProgram`] — the specialized arrangement (grid + loop shape
//!   + tiling decisions), the lowered and probe-verified view templates,
//!   and the tile program — everything that depends only on *shapes*;
//! * [`CompiledProgram::execute`] runs it over concrete tensors, doing only
//!   cheap per-request validation (arity, dtype, exact shape match);
//! * [`PlanCache`] memoizes compiled programs behind a concurrent map
//!   keyed by `(kernel, variant, shape signature)` with LRU eviction and
//!   hit/miss counters — the counters are what the coordinator surfaces
//!   in its metrics, and what the tests use to prove a second same-shape
//!   request does zero specialization work.

use std::borrow::Cow;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use super::native::{KernelDef, Specialization};
use super::scheduler::GridScheduler;
use crate::obs::{ProfileReport, ProfileSnapshot};
use crate::runtime::HostTensor;

/// Cache key: which kernel/variant, specialized for which input shapes.
/// The known serving variants intern to statics, so a warm lookup only
/// allocates the kernel name and the shape signature.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub kernel: String,
    pub variant: Cow<'static, str>,
    pub shapes: Vec<Vec<usize>>,
}

/// Map a variant onto its static spelling when it is one of the known
/// native-served variants (the only ones the registry creates backends
/// for); anything else keeps an owned copy for key fidelity.
fn intern_variant(variant: &str) -> Cow<'static, str> {
    match crate::runtime::NATIVE_VARIANTS.iter().copied().find(|v| *v == variant) {
        Some(v) => Cow::Borrowed(v),
        None => Cow::Owned(variant.to_string()),
    }
}

/// A fully compiled, reusable launch: everything the execute stage needs
/// that depends only on the input shapes.  (The variant a plan was
/// compiled under lives in its [`PlanKey`], not here — execution is
/// identical across the native-served variants.)
pub struct CompiledProgram {
    pub kernel: Arc<KernelDef>,
    /// the input shapes this program was compiled for
    pub shapes: Vec<Vec<usize>>,
    /// the meta (block-size) bindings this program was specialized with
    /// when they differ from the heuristic: `None` for the default
    /// policy, `Some(winner)` for an autotuned plan ([`compile_with_meta`])
    pub meta: Option<Vec<(String, i64)>>,
    /// specialized views + grid/loop geometry + output shapes
    pub spec: Specialization,
    /// execution profile accumulated across launches of this plan;
    /// recording only happens when the report is enabled (`NT_PROFILE=1`
    /// at compile time, or an explicit report via `execute_profiled`)
    pub profile: ProfileReport,
}

impl CompiledProgram {
    /// Execute over concrete tensors.  Per-request work is deliberately
    /// minimal: validate that the inputs match the compiled signature,
    /// then launch the grid — no specialization, no lowering.
    pub fn execute(
        &self,
        inputs: &[HostTensor],
        scheduler: &GridScheduler,
    ) -> Result<Vec<HostTensor>> {
        self.execute_profiled(inputs, scheduler, &self.profile)
    }

    /// [`CompiledProgram::execute`] recording into an explicit
    /// [`ProfileReport`] instead of the plan's own (tests and benches
    /// profile without setting `NT_PROFILE`).
    pub fn execute_profiled(
        &self,
        inputs: &[HostTensor],
        scheduler: &GridScheduler,
        profile: &ProfileReport,
    ) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.shapes.len() {
            bail!(
                "compiled {} expects {} inputs, got {}",
                self.kernel.name,
                self.shapes.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&self.shapes).enumerate() {
            if &t.shape != s {
                bail!(
                    "input {i} shape {:?} does not match the compiled shape {s:?} for {}",
                    t.shape,
                    self.kernel.name
                );
            }
            t.as_f32().map_err(|_| {
                anyhow::anyhow!("compiled {}: input {i} must be f32", self.kernel.name)
            })?;
        }
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        scheduler.run_with(
            &self.kernel.program,
            &self.spec.views,
            &refs,
            &self.spec.output_shapes,
            Some(profile),
        )
    }

    /// The accumulated profile, labeled `"<kernel> <shape sig>"` —
    /// `None` unless profiling is enabled and the plan has executed.
    pub fn profile_snapshot(&self) -> Option<ProfileSnapshot> {
        if !self.profile.is_enabled() {
            return None;
        }
        let shape_refs: Vec<&[usize]> = self.shapes.iter().map(|s| s.as_slice()).collect();
        let label = format!("{} {}", self.kernel.name, crate::obs::shape_sig(&shape_refs));
        let snap = self.profile.snapshot(&label);
        (snap.cells > 0).then_some(snap)
    }
}

/// Compile a kernel for concrete input shapes (the expensive stage:
/// arrangement specialization + affine lowering + probe verification).
pub fn compile(kernel: &Arc<KernelDef>, shapes: &[&[usize]]) -> Result<CompiledProgram> {
    let spec = kernel.specialize_shapes(shapes)?;
    Ok(CompiledProgram {
        kernel: kernel.clone(),
        shapes: shapes.iter().map(|s| s.to_vec()).collect(),
        meta: None,
        spec,
        profile: ProfileReport::from_env(),
    })
}

/// [`compile`] with an explicit meta (block-size) binding set — the
/// autotuner's entry point for candidate configurations.  The candidate
/// runs through the ordinary specializer, so an infeasible block size is
/// a clean error the search skips, never a panic.
pub fn compile_with_meta(
    kernel: &Arc<KernelDef>,
    shapes: &[&[usize]],
    meta: &[(String, i64)],
) -> Result<CompiledProgram> {
    let spec = kernel.specialize_shapes_with_meta(shapes, meta)?;
    Ok(CompiledProgram {
        kernel: kernel.clone(),
        shapes: shapes.iter().map(|s| s.to_vec()).collect(),
        meta: Some(meta.to_vec()),
        spec,
        profile: ProfileReport::from_env(),
    })
}

struct Entry {
    program: Arc<CompiledProgram>,
    /// logical timestamp of the last hit (LRU victim = smallest)
    last_used: u64,
}

struct CacheInner {
    map: HashMap<PlanKey, Entry>,
    /// monotonic logical clock for `last_used`
    tick: u64,
    /// per-kernel (hits, misses) — coarser than the map's (kernel,
    /// variant, shapes) keys, and never evicted, so attribution survives
    /// plan eviction
    per_kernel: HashMap<String, (u64, u64)>,
    /// autotuned winners: meta bindings a miss for this key compiles with
    /// instead of the heuristic.  Never evicted (a handful of small
    /// vectors), so an LRU-evicted tuned plan recompiles straight to its
    /// winner and a table-restored winner compiles lazily on first use —
    /// both with zero re-measurement.
    winners: HashMap<PlanKey, Arc<Vec<(String, i64)>>>,
}

/// Concurrent memoization of compiled programs.  One instance is shared
/// by every coordinator worker (the workers' registries are per-thread,
/// the plan cache is not), so a shape seen by any worker is warm for all.
pub struct PlanCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// Default number of cached plans (shape buckets x kernels).
    pub const DEFAULT_CAPACITY: usize = 256;

    pub fn new(capacity: usize) -> PlanCache {
        PlanCache {
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                tick: 0,
                per_kernel: HashMap::new(),
                winners: HashMap::new(),
            }),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Fetch (compiling on miss) the program for `(kernel, variant,
    /// shapes)`.  Compilation happens under the cache lock, so concurrent
    /// `prepare` calls for the same key specialize exactly once and every
    /// caller receives a clone of the same `Arc`.  The tradeoff is
    /// deliberate: a compile is tens of microseconds and — by this
    /// cache's whole purpose — rare, so a hit briefly queueing behind an
    /// in-flight compile is bounded, while the lock keeps the
    /// exactly-once guarantee free of per-key in-flight bookkeeping.
    /// Hits themselves are O(1) (hash lookup + timestamp bump).
    pub fn prepare(
        &self,
        kernel: &Arc<KernelDef>,
        variant: &str,
        shapes: &[&[usize]],
    ) -> Result<Arc<CompiledProgram>> {
        Ok(self.prepare_with_outcome(kernel, variant, shapes)?.0)
    }

    /// [`PlanCache::prepare`] that also reports whether the lookup was a
    /// hit (`true`) or compiled fresh (`false`) — the per-request plan
    /// attribution the tracer records.
    pub fn prepare_with_outcome(
        &self,
        kernel: &Arc<KernelDef>,
        variant: &str,
        shapes: &[&[usize]],
    ) -> Result<(Arc<CompiledProgram>, bool)> {
        let key = PlanKey {
            kernel: kernel.name.clone(),
            variant: intern_variant(variant),
            shapes: shapes.iter().map(|s| s.to_vec()).collect(),
        };
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        inner.tick += 1;
        let now = inner.tick;
        if let Some(entry) = inner.map.get_mut(&key) {
            entry.last_used = now;
            let compiled = entry.program.clone();
            self.hits.fetch_add(1, Ordering::Relaxed);
            inner.per_kernel.entry(key.kernel).or_insert((0, 0)).0 += 1;
            return Ok((compiled, true));
        }
        // miss: compile while holding the lock (errors are not cached).
        // A key with an installed tuned winner compiles with the winner's
        // block bindings instead of the heuristic's — this is how both an
        // LRU-evicted tuned plan and a tuning-table-restored winner come
        // back without re-searching.
        let compiled = match inner.winners.get(&key) {
            Some(winner) => Arc::new(compile_with_meta(kernel, shapes, winner)?),
            None => Arc::new(compile(kernel, shapes)?),
        };
        self.misses.fetch_add(1, Ordering::Relaxed);
        inner.per_kernel.entry(key.kernel.clone()).or_insert((0, 0)).1 += 1;
        inner.map.insert(key, Entry { program: compiled.clone(), last_used: now });
        // evict the least-recently-used entries (O(n) scan, but only on
        // insert past capacity — never on the hit path)
        while inner.map.len() > self.capacity {
            let Some(cold) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            inner.map.remove(&cold);
        }
        Ok((compiled, false))
    }

    /// Install an autotuned winner for `(kernel, variant, shapes)`: the
    /// meta bindings future misses compile with, plus (optionally) the
    /// already-compiled winning program so the very next `prepare` is a
    /// plain warm hit.  Passing `program: None` records the winner lazily
    /// (the tuning-table restore path — no compilation, no measurement;
    /// the first `prepare` compiles straight to the winner).
    pub fn install_winner(
        &self,
        kernel_name: &str,
        variant: &str,
        shapes: &[&[usize]],
        meta: Vec<(String, i64)>,
        program: Option<Arc<CompiledProgram>>,
    ) {
        let key = PlanKey {
            kernel: kernel_name.to_string(),
            variant: intern_variant(variant),
            shapes: shapes.iter().map(|s| s.to_vec()).collect(),
        };
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        inner.winners.insert(key.clone(), Arc::new(meta));
        if let Some(program) = program {
            inner.tick += 1;
            let now = inner.tick;
            inner.map.insert(key, Entry { program, last_used: now });
            while inner.map.len() > self.capacity {
                let Some(cold) = inner
                    .map
                    .iter()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| k.clone())
                else {
                    break;
                };
                inner.map.remove(&cold);
            }
        }
    }

    /// The installed tuned winner for `(kernel, variant, shapes)`, if any.
    pub fn winner(
        &self,
        kernel_name: &str,
        variant: &str,
        shapes: &[&[usize]],
    ) -> Option<Arc<Vec<(String, i64)>>> {
        let key = PlanKey {
            kernel: kernel_name.to_string(),
            variant: intern_variant(variant),
            shapes: shapes.iter().map(|s| s.to_vec()).collect(),
        };
        self.inner.lock().unwrap().winners.get(&key).cloned()
    }

    /// Number of installed tuned winners (all kernels).
    pub fn tuned_plans(&self) -> usize {
        self.inner.lock().unwrap().winners.len()
    }

    /// Per-kernel count of installed tuned winners, sorted by name.
    pub fn tuned_counters(&self) -> Vec<(String, u64)> {
        let inner = self.inner.lock().unwrap();
        let mut counts: HashMap<&str, u64> = HashMap::new();
        for key in inner.winners.keys() {
            *counts.entry(key.kernel.as_str()).or_insert(0) += 1;
        }
        let mut rows: Vec<(String, u64)> =
            counts.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        rows.sort();
        rows
    }

    /// Per-kernel `(name, hits, misses)`, sorted by kernel name.  Counts
    /// are kernel-level (summed over variants and shapes) and survive
    /// plan eviction.
    pub fn kernel_counters(&self) -> Vec<(String, u64, u64)> {
        let inner = self.inner.lock().unwrap();
        let mut rows: Vec<(String, u64, u64)> = inner
            .per_kernel
            .iter()
            .map(|(k, (h, m))| (k.clone(), *h, *m))
            .collect();
        rows.sort();
        rows
    }

    /// Profile snapshots of every cached plan that has recorded execution
    /// data (non-empty only under `NT_PROFILE=1`), sorted by label.
    pub fn profile_snapshots(&self) -> Vec<ProfileSnapshot> {
        let inner = self.inner.lock().unwrap();
        let mut snaps: Vec<ProfileSnapshot> = inner
            .map
            .values()
            .filter_map(|e| e.program.profile_snapshot())
            .collect();
        snaps.sort_by(|a, b| a.label.cmp(&b.label));
        snaps
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::lookup;
    use crate::prng::SplitMix64;

    fn mm_shapes(m: usize, k: usize, n: usize) -> Vec<Vec<usize>> {
        vec![vec![m, k], vec![k, n]]
    }

    fn refs(shapes: &[Vec<usize>]) -> Vec<&[usize]> {
        shapes.iter().map(|s| s.as_slice()).collect()
    }

    #[test]
    fn hit_and_miss_counting() {
        let cache = PlanCache::new(8);
        let mm = lookup("mm").unwrap();
        let shapes = mm_shapes(40, 30, 20);
        let first = cache.prepare(&mm, "nt", &refs(&shapes)).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let second = cache.prepare(&mm, "nt", &refs(&shapes)).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert!(Arc::ptr_eq(&first, &second), "warm prepare must return the same program");
    }

    #[test]
    fn shape_signature_collisions_get_distinct_plans() {
        // same kernel, same rank, different dims — the signatures must
        // not collide into one plan
        let cache = PlanCache::new(8);
        let mm = lookup("mm").unwrap();
        let a = cache.prepare(&mm, "nt", &refs(&mm_shapes(64, 64, 64))).unwrap();
        let b = cache.prepare(&mm, "nt", &refs(&mm_shapes(64, 64, 32))).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(a.spec.output_shapes, vec![vec![64, 64]]);
        assert_eq!(b.spec.output_shapes, vec![vec![64, 32]]);
        assert_eq!(cache.misses(), 2);
        // variants key separately too
        cache.prepare(&mm, "baseline", &refs(&mm_shapes(64, 64, 64))).unwrap();
        assert_eq!(cache.misses(), 3);
    }

    #[test]
    fn concurrent_prepare_returns_one_arc() {
        let cache = Arc::new(PlanCache::new(8));
        let mm = lookup("mm").unwrap();
        let shapes = mm_shapes(48, 48, 48);
        let mut handles = Vec::new();
        for _ in 0..8 {
            let (cache, shapes, mm) = (cache.clone(), shapes.clone(), mm.clone());
            handles.push(std::thread::spawn(move || {
                cache.prepare(&mm, "nt", &refs(&shapes)).unwrap()
            }));
        }
        let plans: Vec<Arc<CompiledProgram>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(plans.iter().all(|p| Arc::ptr_eq(p, &plans[0])));
        assert_eq!(cache.misses(), 1, "exactly one compilation across 8 threads");
        assert_eq!(cache.hits(), 7);
    }

    #[test]
    fn lru_eviction_respects_capacity() {
        let cache = PlanCache::new(2);
        let mm = lookup("mm").unwrap();
        cache.prepare(&mm, "nt", &refs(&mm_shapes(8, 8, 8))).unwrap();
        cache.prepare(&mm, "nt", &refs(&mm_shapes(8, 8, 16))).unwrap();
        // touch the first so the second is the LRU victim
        cache.prepare(&mm, "nt", &refs(&mm_shapes(8, 8, 8))).unwrap();
        cache.prepare(&mm, "nt", &refs(&mm_shapes(8, 8, 24))).unwrap();
        assert_eq!(cache.len(), 2);
        let miss_before = cache.misses();
        cache.prepare(&mm, "nt", &refs(&mm_shapes(8, 8, 8))).unwrap();
        assert_eq!(cache.misses(), miss_before, "touched entry must have survived");
        cache.prepare(&mm, "nt", &refs(&mm_shapes(8, 8, 16))).unwrap();
        assert_eq!(cache.misses(), miss_before + 1, "LRU victim must recompile");
    }

    #[test]
    fn kernel_counters_attribute_hits_and_misses() {
        let cache = PlanCache::new(8);
        let mm = lookup("mm").unwrap();
        let softmax = lookup("softmax").unwrap();
        cache.prepare(&mm, "nt", &refs(&mm_shapes(8, 8, 8))).unwrap();
        let (_, hit) =
            cache.prepare_with_outcome(&mm, "nt", &refs(&mm_shapes(8, 8, 8))).unwrap();
        assert!(hit, "second same-shape prepare must report a hit");
        let sm_shapes = vec![vec![4usize, 16]];
        cache.prepare(&softmax, "nt", &refs(&sm_shapes)).unwrap();
        let rows = cache.kernel_counters();
        assert_eq!(
            rows,
            vec![("mm".to_string(), 1, 1), ("softmax".to_string(), 0, 1)],
            "per-kernel attribution must match global hit/miss counts"
        );
    }

    #[test]
    fn compile_errors_are_not_cached() {
        let cache = PlanCache::new(8);
        let mm = lookup("mm").unwrap();
        let bad = vec![vec![4usize, 3], vec![5usize, 4]]; // inner-dim mismatch
        assert!(cache.prepare(&mm, "nt", &refs(&bad)).is_err());
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (0, 0, 0));
    }

    #[test]
    fn compiled_program_rejects_mismatched_inputs() {
        let mm = lookup("mm").unwrap();
        let shapes = mm_shapes(16, 8, 12);
        let compiled = compile(&mm, &refs(&shapes)).unwrap();
        let mut rng = SplitMix64::new(5);
        let good_a = HostTensor::randn(vec![16, 8], &mut rng);
        let good_b = HostTensor::randn(vec![8, 12], &mut rng);
        let sched = GridScheduler::serial();
        assert!(compiled.execute(&[good_a.clone(), good_b.clone()], &sched).is_ok());
        // wrong arity
        assert!(compiled.execute(&[good_a.clone()], &sched).is_err());
        // wrong shape
        let wrong = HostTensor::randn(vec![16, 9], &mut rng);
        let err = compiled.execute(&[wrong, good_b], &sched).unwrap_err();
        assert!(format!("{err:#}").contains("compiled shape"), "{err:#}");
    }
}
