//! Native tile-execution backend: a **compile → cache → execute** pipeline
//! that runs arrangements without AOT artifacts.
//!
//! The paper separates *arrangement* (tiling geometry, §3.2) from
//! *application* (per-tile compute, §3.3), and its code generator compiles
//! an arrangement **once** and launches it many times.  This subsystem
//! mirrors that lifecycle explicitly:
//!
//! 1. **compile** ([`compile`]) — specialize a kernel's catalog
//!    arrangement for concrete input shapes: evaluate level sizes, lower
//!    every index expression to affine gather/scatter strides (verified at
//!    probe points), and fix the grid/loop/tiling decisions.  The result
//!    is a [`CompiledProgram`]: the specialized views, the tile program,
//!    and the launch geometry — everything that depends only on shapes.
//! 2. **cache** ([`PlanCache`]) — memoize compiled programs under
//!    `(kernel, variant, shape signature)` with LRU eviction and hit/miss
//!    counters.  A second same-shape request does *zero* specialization or
//!    lowering work; the counters prove it and the coordinator surfaces
//!    them in its serving metrics.
//! 3. **execute** ([`CompiledProgram::execute`]) — cheap per-request
//!    validation (arity, dtype, exact shape), then one grid launch over
//!    the persistent worker pool.
//!
//! The moving parts:
//!
//! * [`tile`] — dense f32 tiles with the `ntl` operation set (dot, exp,
//!   max/sum reductions, broadcastable element-wise arithmetic);
//! * [`gemm`] — the blocked, cache-aware GEMM microkernel behind
//!   `Tile::dot` and the fused `DotAcc` instruction: packed A/B panels,
//!   an MR x NR register tile, strided-window inputs, and optional
//!   intra-tile row parallelism;
//! * [`ir`] — the tile-program IR (load/store/zeros/dot/exp/max/sum/
//!   broadcast/elementwise/transpose/pad-mask + one **loop-carried**
//!   loop construct: declared carry registers persist across sub-tile
//!   iterations, everything else is iteration-local) and its
//!   interpreter: the serial per-program semantics of the paper;
//! * [`view`] — strided [`view::ParamView`]s: an arrangement's index
//!   expressions lowered (and probe-verified) to affine gather/scatter
//!   over [`crate::runtime::HostTensor`] buffers, with pad-value edges;
//! * [`native`] — resolution façade over [`crate::kernel`]: kernels are
//!   *declared* through `kernel::make(arrangement, application, tensors)`
//!   (the paper's §3.1 API) and registered in the global
//!   `kernel::KernelRegistry`; shape checks, output inference, the
//!   per-shape specializer and the coalescing eligibility flag are all
//!   derived from the declaration;
//! * [`compile`] — the compile stage and the concurrent [`PlanCache`];
//! * [`pool`] — the **persistent worker pool** every parallel execution
//!   shares: grid launches and `DotAcc`'s intra-tile row split dispatch
//!   borrowed jobs to long-lived threads instead of spawning scoped
//!   threads per run;
//! * [`scheduler`] — the grid scheduler: one program instance per
//!   outermost-level cell, chunked across the pool exactly as the code
//!   generator would launch the grid.  Under `NT_PROFILE=1` it feeds the
//!   plan-attached [`crate::obs::ProfileReport`] with per-instruction and
//!   per-cell wall time (`repro stats` renders the report);
//! * [`reference`] — straightforward oracle implementations the tile
//!   programs are cross-checked against in `cargo test`;
//! * [`tune`] — the per-shape block-size autotuner (`NT_TUNE`): searches
//!   each `Meta` policy's candidate space on first use, installs the
//!   winner in the [`PlanCache`], and persists it to an on-disk tuning
//!   table (`NT_TUNE_TABLE`) so a restart restores winners with zero
//!   re-measurement.
//!
//! The coordinator reaches this subsystem through the
//! [`crate::runtime::Backend`] trait's `prepare`/`execute` split: the
//! router resolves a request to a backend, `prepare(shapes)` returns the
//! cached [`CompiledProgram`] handle (hit or miss), and `execute` runs it.
//! Same-shape requests for row-independent kernels are additionally
//! *coalesced* by the batcher — stacked along dim 0 into one grid launch
//! and split back on reply, bit-identically to per-request execution.

pub mod compile;
pub mod gemm;
pub mod ir;
pub mod native;
pub mod pool;
pub mod reference;
pub mod scheduler;
pub mod tile;
pub mod tune;
pub mod view;

pub use compile::{compile, compile_with_meta, CompiledProgram, PlanCache, PlanKey};
pub use ir::{Instr, TileProgram};
pub use native::{kernels, lookup, KernelDef, Specialization};
pub use pool::WorkerPool;
pub use scheduler::GridScheduler;
pub use tile::{BinOp, ReduceOp, Tile, UnaryOp};
pub use tune::{TuneMode, TuneOutcome, TuneTable, Tuner};
pub use view::ParamView;

use anyhow::{anyhow, Result};

use crate::runtime::HostTensor;

/// Convenience entry point: execute a native kernel by name
/// (compile-and-execute, uncached — serving paths go through
/// [`PlanCache`] via the registry's backends instead).
pub fn run_native(
    name: &str,
    inputs: &[HostTensor],
    scheduler: &GridScheduler,
) -> Result<Vec<HostTensor>> {
    let kernel = lookup(name)
        .ok_or_else(|| anyhow!("kernel {name:?} has no native tile program"))?;
    kernel.run(inputs, scheduler)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::SplitMix64;

    const TOL: f32 = 1e-4;

    /// Serializes the tests that flip the process-global naive-dot
    /// override — without it the two could interleave and observe each
    /// other's flag state mid-assertion.
    static NAIVE_FLAG_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn randn(shape: &[usize], rng: &mut SplitMix64) -> HostTensor {
        HostTensor::randn(shape.to_vec(), rng)
    }

    /// Native (serial and pooled) vs reference, asserting max|diff| ≤ 1e-4.
    fn check(name: &str, inputs: &[HostTensor]) {
        let expected = reference::run(name, inputs).expect("reference");
        for scheduler in [GridScheduler::serial(), GridScheduler::pooled(4)] {
            let got = run_native(name, inputs, &scheduler).expect(name);
            assert_eq!(got.len(), expected.len());
            for (g, e) in got.iter().zip(&expected) {
                assert_eq!(g.shape, e.shape, "{name} output shape");
                let diff = g.max_abs_diff(e).unwrap();
                assert!(
                    diff <= TOL,
                    "{name} ({} threads): max|diff| = {diff}",
                    scheduler.threads
                );
            }
        }
    }

    #[test]
    fn native_add_matches_reference() {
        let mut rng = SplitMix64::new(11);
        // 1000 is not a multiple of the 1024 block: exercises edge padding
        let x = randn(&[1000], &mut rng);
        let y = randn(&[1000], &mut rng);
        check("add", &[x, y]);
    }

    #[test]
    fn native_silu_matches_reference() {
        let mut rng = SplitMix64::new(12);
        let x = randn(&[777], &mut rng);
        check("silu", &[x]);
    }

    #[test]
    fn native_gelu_matches_reference() {
        let mut rng = SplitMix64::new(25);
        let x = randn(&[1023], &mut rng);
        check("gelu", &[x]);
    }

    #[test]
    fn native_layer_norm_matches_reference() {
        let mut rng = SplitMix64::new(26);
        let x = randn(&[9, 263], &mut rng);
        check("layer_norm", &[x]);
    }

    #[test]
    fn native_softmax_matches_reference() {
        let mut rng = SplitMix64::new(13);
        let x = randn(&[7, 301], &mut rng);
        check("softmax", &[x]);
    }

    #[test]
    fn native_rms_norm_matches_reference() {
        let mut rng = SplitMix64::new(14);
        let x = randn(&[5, 257], &mut rng);
        check("rms_norm", &[x]);
    }

    #[test]
    fn native_mm_matches_reference() {
        let mut rng = SplitMix64::new(15);
        // deliberately not multiples of the 32-wide blocks
        let a = randn(&[70, 50], &mut rng);
        let b = randn(&[50, 90], &mut rng);
        check("mm", &[a, b]);
    }

    #[test]
    fn native_bmm_matches_reference() {
        let mut rng = SplitMix64::new(16);
        let a = randn(&[3, 33, 17], &mut rng);
        let b = randn(&[3, 17, 29], &mut rng);
        check("bmm", &[a, b]);
    }

    #[test]
    fn native_addmm_matches_reference_for_all_bias_ranks() {
        // the broadcast epilogue across every admitted bias shape: [n],
        // [1, n] (row broadcast) and [m, n] (full), on ragged tile edges
        let mut rng = SplitMix64::new(27);
        for (m, k, n) in [(70, 50, 90), (3, 7, 5), (33, 127, 31)] {
            let a = randn(&[m, k], &mut rng);
            let b = randn(&[k, n], &mut rng);
            for bias_shape in [vec![n], vec![1, n], vec![m, n]] {
                let bias = randn(&bias_shape, &mut rng);
                check("addmm", &[bias, a.clone(), b.clone()]);
            }
        }
    }

    #[test]
    fn native_addmm_rejects_non_broadcastable_bias() {
        let mut rng = SplitMix64::new(28);
        let a = randn(&[8, 4], &mut rng);
        let b = randn(&[4, 6], &mut rng);
        // [5]/[8, 5] fail size-symbol unification, [2, 6] fails the
        // broadcast constraint, [1, 1, 6] fails the rank check — all are
        // derived preconditions, all clean admission errors
        for bad in [vec![5usize], vec![8, 5], vec![2, 6], vec![1, 1, 6]] {
            let bias = randn(&bad, &mut rng);
            let err = run_native(
                "addmm",
                &[bias, a.clone(), b.clone()],
                &GridScheduler::serial(),
            )
            .unwrap_err();
            assert!(format!("{err:#}").contains("addmm"), "{bad:?}: {err:#}");
        }
        let bias = randn(&[2, 6], &mut rng);
        let err = run_native("addmm", &[bias, a, b], &GridScheduler::serial()).unwrap_err();
        assert!(format!("{err:#}").contains("broadcast"), "{err:#}");
    }

    #[test]
    fn native_mm_exact_tiles() {
        // block-aligned case: no padding path at all
        let mut rng = SplitMix64::new(17);
        let a = randn(&[64, 64], &mut rng);
        let b = randn(&[64, 64], &mut rng);
        check("mm", &[a, b]);
    }

    #[test]
    fn native_mm_odd_and_prime_shapes() {
        // property-style sweep: 1x1, primes, and ragged edges — every
        // grid cell mixes dense-window and gather-fallback DotAcc paths
        let mut rng = SplitMix64::new(19);
        for (m, k, n) in [(1, 1, 1), (3, 7, 5), (127, 129, 65), (33, 127, 31)] {
            let a = randn(&[m, k], &mut rng);
            let b = randn(&[k, n], &mut rng);
            check("mm", &[a, b]);
        }
    }

    #[test]
    fn native_mm_large_blocks_with_padded_k_tail() {
        // > 128 on every dim: the 64x64x256 tiling kicks in; k = 300
        // leaves a padded tail tile, so dense windows and gather
        // fallbacks both execute within one request
        let mut rng = SplitMix64::new(20);
        let a = randn(&[160, 300], &mut rng);
        let b = randn(&[300, 130], &mut rng);
        // deeper k than the 1e-4 smoke shapes: use the ISSUE's blocked-
        // vs-oracle bound
        let expected = reference::run("mm", &[a.clone(), b.clone()]).unwrap();
        for scheduler in [GridScheduler::serial(), GridScheduler::pooled(4)] {
            let got = run_native("mm", &[a.clone(), b.clone()], &scheduler).unwrap();
            let diff = got[0].max_abs_diff(&expected[0]).unwrap();
            assert!(diff <= 1e-3, "mm ({} threads): max|diff| = {diff}", scheduler.threads);
        }
    }

    #[test]
    fn native_mm_single_cell_uses_intra_tile_parallelism() {
        // grid [1, 1] with a deep k-loop: the pooled scheduler hands the
        // pool to the cell and DotAcc row-splits the microkernel — the
        // result must still match the reference oracle
        let mut rng = SplitMix64::new(22);
        let a = randn(&[64, 2048], &mut rng);
        let b = randn(&[2048, 64], &mut rng);
        let spec = lookup("mm").unwrap().specialize(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(spec.grid, vec![1, 1], "intended single-cell launch");
        // k = 2048 accumulates too deep for the 1e-4 bound; the ISSUE
        // acceptance tolerance for blocked-vs-oracle is 1e-3
        let expected = reference::run("mm", &[a.clone(), b.clone()]).unwrap();
        for scheduler in [GridScheduler::serial(), GridScheduler::pooled(4)] {
            let got = run_native("mm", &[a.clone(), b.clone()], &scheduler).unwrap();
            let diff = got[0].max_abs_diff(&expected[0]).unwrap();
            assert!(diff <= 1e-3, "mm ({} threads): max|diff| = {diff}", scheduler.threads);
        }
    }

    #[test]
    fn naive_dot_override_forces_oracle_path() {
        // genuinely flip the flag: Tile::dot must route to the naive
        // loop and DotAcc must take its gather + dot_naive + add oracle
        // branch — both compute the same function, so a concurrent test
        // momentarily seeing the naive path stays correct
        use super::tile::{naive_dot_forced, set_naive_dot_forced};
        let _guard = NAIVE_FLAG_LOCK.lock().unwrap();
        let mut rng = SplitMix64::new(24);
        let a = randn(&[70, 130], &mut rng);
        let b = randn(&[130, 90], &mut rng);
        let blocked = run_native("mm", &[a.clone(), b.clone()], &GridScheduler::serial()).unwrap();
        set_naive_dot_forced(true);
        assert!(naive_dot_forced(), "override must be visible");
        let forced = run_native("mm", &[a.clone(), b.clone()], &GridScheduler::serial());
        let t = Tile::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let u = Tile::new(vec![3, 2], vec![6.0, 5.0, 4.0, 3.0, 2.0, 1.0]).unwrap();
        let via_flag = t.dot(&u).unwrap();
        set_naive_dot_forced(false);
        // dot under the flag must be bit-identical to the explicit oracle
        assert_eq!(via_flag, t.dot_naive(&u).unwrap());
        let diff = forced.unwrap()[0].max_abs_diff(&blocked[0]).unwrap();
        assert!(diff <= 1e-3, "oracle (forced naive) vs blocked mm: max|diff| = {diff}");
    }

    #[test]
    fn naive_dot_override_bypasses_blocked_gemm_through_cached_program() {
        // the flag is an *execution-time* decision: a program compiled and
        // cached while the blocked path was active must still take the
        // naive oracle branch once the flag flips — bit-identically to a
        // freshly specialized run under the same flag
        use super::tile::set_naive_dot_forced;
        let _guard = NAIVE_FLAG_LOCK.lock().unwrap();
        let mut rng = SplitMix64::new(29);
        let a = randn(&[70, 130], &mut rng);
        let b = randn(&[130, 90], &mut rng);
        let cache = PlanCache::new(4);
        let mm = lookup("mm").unwrap();
        let shapes: Vec<&[usize]> = [&a, &b].iter().map(|t| t.shape.as_slice()).collect();
        let compiled = cache.prepare(&mm, "nt", &shapes).unwrap();
        let sched = GridScheduler::serial();
        let blocked = compiled.execute(&[a.clone(), b.clone()], &sched).unwrap();
        set_naive_dot_forced(true);
        let via_cache = compiled.execute(&[a.clone(), b.clone()], &sched).unwrap();
        let fresh = run_native("mm", &[a.clone(), b.clone()], &sched).unwrap();
        set_naive_dot_forced(false);
        assert_eq!(
            via_cache[0], fresh[0],
            "cached program under the flag must equal a fresh naive-path run bitwise"
        );
        let diff = via_cache[0].max_abs_diff(&blocked[0]).unwrap();
        assert!(diff <= 1e-3, "naive vs blocked through one cached program: {diff}");
        assert_eq!(cache.hits(), 0);
        assert_eq!(cache.misses(), 1, "no recompilation happened around the flag flip");
    }

    #[test]
    fn coalesced_execution_is_bit_identical_for_stackable_kernels() {
        // the batcher's native coalescing contract: stacking same-shape
        // requests along dim 0 and splitting the outputs back must equal
        // per-request execution *bitwise* for every coalescible kernel
        use crate::coordinator::Coalescer;
        let mut rng = SplitMix64::new(30);
        let sched = GridScheduler::pooled(4);
        for kernel in kernels().iter().filter(|k| k.coalesce) {
            let per_request: Vec<Vec<HostTensor>> = (0..3)
                .map(|_| {
                    crate::harness::golden::native_task_inputs(&kernel.name, &mut rng).unwrap()
                })
                .collect();
            let singles: Vec<Vec<HostTensor>> = per_request
                .iter()
                .map(|inputs| kernel.run(inputs, &sched).unwrap())
                .collect();
            let refs: Vec<Vec<&HostTensor>> =
                per_request.iter().map(|inputs| inputs.iter().collect()).collect();
            let stacked = Coalescer::stack(&refs).unwrap();
            let outs = kernel.run(&stacked, &sched).unwrap();
            let unstacked = Coalescer::unstack(3, outs).unwrap();
            for (got, want) in unstacked.iter().zip(&singles) {
                for (g, w) in got.iter().zip(want) {
                    assert_eq!(g, w, "{}: coalesced != per-request (bitwise)", kernel.name);
                }
            }
        }
    }

    #[test]
    fn zero_and_scalar_inputs_rejected() {
        let empty = HostTensor::f32(vec![0], vec![]).unwrap();
        let scalar = HostTensor::f32(vec![], vec![1.0]).unwrap();
        let sched = GridScheduler::serial();
        for bad in [empty, scalar] {
            let err = run_native("silu", &[bad.clone()], &sched).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("zero-length") || msg.contains("rank-0"),
                "unexpected error: {msg}"
            );
        }
    }

    #[test]
    fn unknown_kernel_is_a_clean_error() {
        let sched = GridScheduler::serial();
        let x = HostTensor::f32(vec![4], vec![1.0; 4]).unwrap();
        assert!(run_native("conv99", &[x], &sched).is_err());
    }

    #[test]
    fn specialization_reports_launch_geometry() {
        let mut rng = SplitMix64::new(18);
        let a = randn(&[70, 50], &mut rng);
        let b = randn(&[50, 90], &mut rng);
        let spec = lookup("mm").unwrap().specialize(&[a, b]).unwrap();
        // cdiv(70,32) = 3, cdiv(90,32) = 3, k-loop cdiv(50,32) = 2
        assert_eq!(spec.grid, vec![3, 3]);
        assert_eq!(spec.loop_shape, vec![2]);
        assert_eq!(spec.programs(), 9);
        assert_eq!(spec.output_shapes, vec![vec![70, 90]]);
    }
}
