//! Native tile-execution backend: run arrangements without AOT artifacts.
//!
//! The paper separates *arrangement* (tiling geometry, §3.2) from
//! *application* (per-tile compute, §3.3).  The rest of this crate mirrors
//! the arrangement algebra symbolically; this subsystem closes the loop by
//! actually **executing** applications over arranged tiles:
//!
//! * [`tile`] — dense f32 tiles with the `ntl` operation set (dot, exp,
//!   max/sum reductions, broadcastable element-wise arithmetic);
//! * [`ir`] — the tile-program IR (load/store/zeros/loop + compute ops)
//!   and its interpreter: the serial per-program semantics of the paper;
//! * [`view`] — strided [`view::ParamView`]s: an arrangement's index
//!   expressions lowered (and verified) to affine gather/scatter over
//!   [`crate::runtime::HostTensor`] buffers, with pad-value edge handling;
//! * [`scheduler`] — the grid scheduler: one program instance per
//!   outermost-level cell, auto-parallelized over a std-only worker pool
//!   exactly as the code generator would launch the grid;
//! * [`native`] — the kernel catalog (add, silu, softmax, rms_norm, mm,
//!   bmm): arrangement specializers + tile programs, shape-polymorphic
//!   per request;
//! * [`reference`] — straightforward oracle implementations the tile
//!   programs are cross-checked against in `cargo test`.
//!
//! The coordinator reaches this subsystem through the
//! [`crate::runtime::Backend`] trait: when a (kernel, variant) has no AOT
//! artifact — or no PJRT runtime exists at all, as in the offline build —
//! the registry falls back to native execution transparently.

pub mod ir;
pub mod native;
pub mod reference;
pub mod scheduler;
pub mod tile;
pub mod view;

pub use ir::{Instr, TileProgram};
pub use native::{kernels, lookup, NativeKernel, Specialization};
pub use scheduler::GridScheduler;
pub use tile::{BinOp, ReduceOp, Tile, UnaryOp};
pub use view::ParamView;

use anyhow::{anyhow, Result};

use crate::runtime::HostTensor;

/// Convenience entry point: execute a native kernel by name.
pub fn run_native(
    name: &str,
    inputs: &[HostTensor],
    scheduler: &GridScheduler,
) -> Result<Vec<HostTensor>> {
    let kernel = lookup(name)
        .ok_or_else(|| anyhow!("kernel {name:?} has no native tile program"))?;
    kernel.run(inputs, scheduler)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::SplitMix64;

    const TOL: f32 = 1e-4;

    fn randn(shape: &[usize], rng: &mut SplitMix64) -> HostTensor {
        HostTensor::randn(shape.to_vec(), rng)
    }

    /// Native (serial and pooled) vs reference, asserting max|diff| ≤ 1e-4.
    fn check(name: &str, inputs: &[HostTensor]) {
        let expected = reference::run(name, inputs).expect("reference");
        for scheduler in [GridScheduler::serial(), GridScheduler::pooled(4)] {
            let got = run_native(name, inputs, &scheduler).expect(name);
            assert_eq!(got.len(), expected.len());
            for (g, e) in got.iter().zip(&expected) {
                assert_eq!(g.shape, e.shape, "{name} output shape");
                let diff = g.max_abs_diff(e).unwrap();
                assert!(
                    diff <= TOL,
                    "{name} ({} threads): max|diff| = {diff}",
                    scheduler.threads
                );
            }
        }
    }

    #[test]
    fn native_add_matches_reference() {
        let mut rng = SplitMix64::new(11);
        // 1000 is not a multiple of the 1024 block: exercises edge padding
        let x = randn(&[1000], &mut rng);
        let y = randn(&[1000], &mut rng);
        check("add", &[x, y]);
    }

    #[test]
    fn native_silu_matches_reference() {
        let mut rng = SplitMix64::new(12);
        let x = randn(&[777], &mut rng);
        check("silu", &[x]);
    }

    #[test]
    fn native_softmax_matches_reference() {
        let mut rng = SplitMix64::new(13);
        let x = randn(&[7, 301], &mut rng);
        check("softmax", &[x]);
    }

    #[test]
    fn native_rms_norm_matches_reference() {
        let mut rng = SplitMix64::new(14);
        let x = randn(&[5, 257], &mut rng);
        check("rms_norm", &[x]);
    }

    #[test]
    fn native_mm_matches_reference() {
        let mut rng = SplitMix64::new(15);
        // deliberately not multiples of the 32-wide blocks
        let a = randn(&[70, 50], &mut rng);
        let b = randn(&[50, 90], &mut rng);
        check("mm", &[a, b]);
    }

    #[test]
    fn native_bmm_matches_reference() {
        let mut rng = SplitMix64::new(16);
        let a = randn(&[3, 33, 17], &mut rng);
        let b = randn(&[3, 17, 29], &mut rng);
        check("bmm", &[a, b]);
    }

    #[test]
    fn native_mm_exact_tiles() {
        // block-aligned case: no padding path at all
        let mut rng = SplitMix64::new(17);
        let a = randn(&[64, 64], &mut rng);
        let b = randn(&[64, 64], &mut rng);
        check("mm", &[a, b]);
    }

    #[test]
    fn zero_and_scalar_inputs_rejected() {
        let empty = HostTensor::f32(vec![0], vec![]).unwrap();
        let scalar = HostTensor::f32(vec![], vec![1.0]).unwrap();
        let sched = GridScheduler::serial();
        for bad in [empty, scalar] {
            let err = run_native("silu", &[bad.clone()], &sched).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("zero-length") || msg.contains("rank-0"),
                "unexpected error: {msg}"
            );
        }
    }

    #[test]
    fn unknown_kernel_is_a_clean_error() {
        let sched = GridScheduler::serial();
        let x = HostTensor::f32(vec![4], vec![1.0; 4]).unwrap();
        assert!(run_native("conv99", &[x], &sched).is_err());
    }

    #[test]
    fn specialization_reports_launch_geometry() {
        let mut rng = SplitMix64::new(18);
        let a = randn(&[70, 50], &mut rng);
        let b = randn(&[50, 90], &mut rng);
        let spec = lookup("mm").unwrap().specialize(&[a, b]).unwrap();
        // cdiv(70,32) = 3, cdiv(90,32) = 3, k-loop cdiv(50,32) = 2
        assert_eq!(spec.grid, vec![3, 3]);
        assert_eq!(spec.loop_shape, vec![2]);
        assert_eq!(spec.programs(), 9);
        assert_eq!(spec.output_shapes, vec![vec![70, 90]]);
    }
}
