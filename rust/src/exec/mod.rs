//! Native tile-execution backend: run arrangements without AOT artifacts.
//!
//! The paper separates *arrangement* (tiling geometry, §3.2) from
//! *application* (per-tile compute, §3.3).  The rest of this crate mirrors
//! the arrangement algebra symbolically; this subsystem closes the loop by
//! actually **executing** applications over arranged tiles:
//!
//! * [`tile`] — dense f32 tiles with the `ntl` operation set (dot, exp,
//!   max/sum reductions, broadcastable element-wise arithmetic);
//! * [`gemm`] — the blocked, cache-aware GEMM microkernel behind
//!   `Tile::dot` and the fused `DotAcc` instruction: packed A/B panels,
//!   an MR x NR register tile, strided-window inputs, and optional
//!   intra-tile row parallelism;
//! * [`ir`] — the tile-program IR (load/store/zeros/loop + compute ops)
//!   and its interpreter: the serial per-program semantics of the paper;
//! * [`view`] — strided [`view::ParamView`]s: an arrangement's index
//!   expressions lowered (and verified) to affine gather/scatter over
//!   [`crate::runtime::HostTensor`] buffers, with pad-value edge handling;
//! * [`scheduler`] — the grid scheduler: one program instance per
//!   outermost-level cell, auto-parallelized over a std-only worker pool
//!   exactly as the code generator would launch the grid;
//! * [`native`] — the kernel catalog (add, silu, gelu, softmax,
//!   rms_norm, layer_norm, mm, bmm): arrangement specializers + tile
//!   programs, shape-polymorphic per request;
//! * [`reference`] — straightforward oracle implementations the tile
//!   programs are cross-checked against in `cargo test`.
//!
//! The coordinator reaches this subsystem through the
//! [`crate::runtime::Backend`] trait: when a (kernel, variant) has no AOT
//! artifact — or no PJRT runtime exists at all, as in the offline build —
//! the registry falls back to native execution transparently.

pub mod gemm;
pub mod ir;
pub mod native;
pub mod reference;
pub mod scheduler;
pub mod tile;
pub mod view;

pub use ir::{Instr, TileProgram};
pub use native::{kernels, lookup, NativeKernel, Specialization};
pub use scheduler::GridScheduler;
pub use tile::{BinOp, ReduceOp, Tile, UnaryOp};
pub use view::ParamView;

use anyhow::{anyhow, Result};

use crate::runtime::HostTensor;

/// Convenience entry point: execute a native kernel by name.
pub fn run_native(
    name: &str,
    inputs: &[HostTensor],
    scheduler: &GridScheduler,
) -> Result<Vec<HostTensor>> {
    let kernel = lookup(name)
        .ok_or_else(|| anyhow!("kernel {name:?} has no native tile program"))?;
    kernel.run(inputs, scheduler)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::SplitMix64;

    const TOL: f32 = 1e-4;

    fn randn(shape: &[usize], rng: &mut SplitMix64) -> HostTensor {
        HostTensor::randn(shape.to_vec(), rng)
    }

    /// Native (serial and pooled) vs reference, asserting max|diff| ≤ 1e-4.
    fn check(name: &str, inputs: &[HostTensor]) {
        let expected = reference::run(name, inputs).expect("reference");
        for scheduler in [GridScheduler::serial(), GridScheduler::pooled(4)] {
            let got = run_native(name, inputs, &scheduler).expect(name);
            assert_eq!(got.len(), expected.len());
            for (g, e) in got.iter().zip(&expected) {
                assert_eq!(g.shape, e.shape, "{name} output shape");
                let diff = g.max_abs_diff(e).unwrap();
                assert!(
                    diff <= TOL,
                    "{name} ({} threads): max|diff| = {diff}",
                    scheduler.threads
                );
            }
        }
    }

    #[test]
    fn native_add_matches_reference() {
        let mut rng = SplitMix64::new(11);
        // 1000 is not a multiple of the 1024 block: exercises edge padding
        let x = randn(&[1000], &mut rng);
        let y = randn(&[1000], &mut rng);
        check("add", &[x, y]);
    }

    #[test]
    fn native_silu_matches_reference() {
        let mut rng = SplitMix64::new(12);
        let x = randn(&[777], &mut rng);
        check("silu", &[x]);
    }

    #[test]
    fn native_gelu_matches_reference() {
        let mut rng = SplitMix64::new(25);
        let x = randn(&[1023], &mut rng);
        check("gelu", &[x]);
    }

    #[test]
    fn native_layer_norm_matches_reference() {
        let mut rng = SplitMix64::new(26);
        let x = randn(&[9, 263], &mut rng);
        check("layer_norm", &[x]);
    }

    #[test]
    fn native_softmax_matches_reference() {
        let mut rng = SplitMix64::new(13);
        let x = randn(&[7, 301], &mut rng);
        check("softmax", &[x]);
    }

    #[test]
    fn native_rms_norm_matches_reference() {
        let mut rng = SplitMix64::new(14);
        let x = randn(&[5, 257], &mut rng);
        check("rms_norm", &[x]);
    }

    #[test]
    fn native_mm_matches_reference() {
        let mut rng = SplitMix64::new(15);
        // deliberately not multiples of the 32-wide blocks
        let a = randn(&[70, 50], &mut rng);
        let b = randn(&[50, 90], &mut rng);
        check("mm", &[a, b]);
    }

    #[test]
    fn native_bmm_matches_reference() {
        let mut rng = SplitMix64::new(16);
        let a = randn(&[3, 33, 17], &mut rng);
        let b = randn(&[3, 17, 29], &mut rng);
        check("bmm", &[a, b]);
    }

    #[test]
    fn native_mm_exact_tiles() {
        // block-aligned case: no padding path at all
        let mut rng = SplitMix64::new(17);
        let a = randn(&[64, 64], &mut rng);
        let b = randn(&[64, 64], &mut rng);
        check("mm", &[a, b]);
    }

    #[test]
    fn native_mm_odd_and_prime_shapes() {
        // property-style sweep: 1x1, primes, and ragged edges — every
        // grid cell mixes dense-window and gather-fallback DotAcc paths
        let mut rng = SplitMix64::new(19);
        for (m, k, n) in [(1, 1, 1), (3, 7, 5), (127, 129, 65), (33, 127, 31)] {
            let a = randn(&[m, k], &mut rng);
            let b = randn(&[k, n], &mut rng);
            check("mm", &[a, b]);
        }
    }

    #[test]
    fn native_mm_large_blocks_with_padded_k_tail() {
        // > 128 on every dim: the 64x64x256 tiling kicks in; k = 300
        // leaves a padded tail tile, so dense windows and gather
        // fallbacks both execute within one request
        let mut rng = SplitMix64::new(20);
        let a = randn(&[160, 300], &mut rng);
        let b = randn(&[300, 130], &mut rng);
        // deeper k than the 1e-4 smoke shapes: use the ISSUE's blocked-
        // vs-oracle bound
        let expected = reference::run("mm", &[a.clone(), b.clone()]).unwrap();
        for scheduler in [GridScheduler::serial(), GridScheduler::pooled(4)] {
            let got = run_native("mm", &[a.clone(), b.clone()], &scheduler).unwrap();
            let diff = got[0].max_abs_diff(&expected[0]).unwrap();
            assert!(diff <= 1e-3, "mm ({} threads): max|diff| = {diff}", scheduler.threads);
        }
    }

    #[test]
    fn native_mm_single_cell_uses_intra_tile_parallelism() {
        // grid [1, 1] with a deep k-loop: the pooled scheduler hands the
        // pool to the cell and DotAcc row-splits the microkernel — the
        // result must still match the reference oracle
        let mut rng = SplitMix64::new(22);
        let a = randn(&[64, 2048], &mut rng);
        let b = randn(&[2048, 64], &mut rng);
        let spec = lookup("mm").unwrap().specialize(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(spec.grid, vec![1, 1], "intended single-cell launch");
        // k = 2048 accumulates too deep for the 1e-4 bound; the ISSUE
        // acceptance tolerance for blocked-vs-oracle is 1e-3
        let expected = reference::run("mm", &[a.clone(), b.clone()]).unwrap();
        for scheduler in [GridScheduler::serial(), GridScheduler::pooled(4)] {
            let got = run_native("mm", &[a.clone(), b.clone()], &scheduler).unwrap();
            let diff = got[0].max_abs_diff(&expected[0]).unwrap();
            assert!(diff <= 1e-3, "mm ({} threads): max|diff| = {diff}", scheduler.threads);
        }
    }

    #[test]
    fn naive_dot_override_forces_oracle_path() {
        // genuinely flip the flag: Tile::dot must route to the naive
        // loop and DotAcc must take its gather + dot_naive + add oracle
        // branch — both compute the same function, so a concurrent test
        // momentarily seeing the naive path stays correct
        use super::tile::{naive_dot_forced, set_naive_dot_forced};
        let mut rng = SplitMix64::new(24);
        let a = randn(&[70, 130], &mut rng);
        let b = randn(&[130, 90], &mut rng);
        let blocked = run_native("mm", &[a.clone(), b.clone()], &GridScheduler::serial()).unwrap();
        set_naive_dot_forced(true);
        assert!(naive_dot_forced(), "override must be visible");
        let forced = run_native("mm", &[a.clone(), b.clone()], &GridScheduler::serial());
        let t = Tile::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let u = Tile::new(vec![3, 2], vec![6.0, 5.0, 4.0, 3.0, 2.0, 1.0]).unwrap();
        let via_flag = t.dot(&u).unwrap();
        set_naive_dot_forced(false);
        // dot under the flag must be bit-identical to the explicit oracle
        assert_eq!(via_flag, t.dot_naive(&u).unwrap());
        let diff = forced.unwrap()[0].max_abs_diff(&blocked[0]).unwrap();
        assert!(diff <= 1e-3, "oracle (forced naive) vs blocked mm: max|diff| = {diff}");
    }

    #[test]
    fn zero_and_scalar_inputs_rejected() {
        let empty = HostTensor::f32(vec![0], vec![]).unwrap();
        let scalar = HostTensor::f32(vec![], vec![1.0]).unwrap();
        let sched = GridScheduler::serial();
        for bad in [empty, scalar] {
            let err = run_native("silu", &[bad.clone()], &sched).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("zero-length") || msg.contains("rank-0"),
                "unexpected error: {msg}"
            );
        }
    }

    #[test]
    fn unknown_kernel_is_a_clean_error() {
        let sched = GridScheduler::serial();
        let x = HostTensor::f32(vec![4], vec![1.0; 4]).unwrap();
        assert!(run_native("conv99", &[x], &sched).is_err());
    }

    #[test]
    fn specialization_reports_launch_geometry() {
        let mut rng = SplitMix64::new(18);
        let a = randn(&[70, 50], &mut rng);
        let b = randn(&[50, 90], &mut rng);
        let spec = lookup("mm").unwrap().specialize(&[a, b]).unwrap();
        // cdiv(70,32) = 3, cdiv(90,32) = 3, k-loop cdiv(50,32) = 2
        assert_eq!(spec.grid, vec![3, 3]);
        assert_eq!(spec.loop_shape, vec![2]);
        assert_eq!(spec.programs(), 9);
        assert_eq!(spec.output_shapes, vec![vec![70, 90]]);
    }
}
