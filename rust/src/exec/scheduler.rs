//! Parallel grid scheduler: run a tile program once per grid cell across
//! the persistent worker pool.
//!
//! The paper's execution model is serial per program instance and
//! embarrassingly parallel across the grid — the code generator emits one
//! Triton program per outermost-level cell.  This scheduler reproduces
//! that: grid cells are split into contiguous chunks and dispatched to
//! [`super::pool`] (no per-run thread spawns), and every chunk writes the
//! shared output buffers directly.  `threads` is a *budget*, not a thread
//! count: it bounds how many chunks one launch fans out, so concurrent
//! launches share the pool instead of oversubscribing the machine.
//!
//! # Safety
//!
//! Workers write outputs through a raw pointer ([`SharedOut`]).  This is
//! sound because the §3.2.1 non-overlap property of valid arrangements
//! guarantees distinct grid cells scatter to *disjoint* output offsets.
//! `run` enforces the property before parallelizing: every output view
//! must vary with every non-trivial grid dimension (checked against the
//! affine-lowered cell coefficients), so no two threads ever write the
//! same element.  The unsafe surface is confined to the single write in
//! `run_cells`.

use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use super::ir::{exec_cell, ParamData, TileProgram};
use super::view::ParamView;
use crate::obs::ProfileReport;
use crate::runtime::HostTensor;

/// Raw output pointer that may cross thread boundaries (see module docs).
#[derive(Clone, Copy)]
struct SharedOut(*mut f32, usize);

unsafe impl Send for SharedOut {}
unsafe impl Sync for SharedOut {}

#[derive(Debug, Clone)]
pub struct GridScheduler {
    /// parallelism budget (chunks dispatched to the persistent pool);
    /// 1 = serial execution on the caller's thread
    pub threads: usize,
}

impl Default for GridScheduler {
    fn default() -> Self {
        GridScheduler {
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    }
}

impl GridScheduler {
    pub fn serial() -> GridScheduler {
        GridScheduler { threads: 1 }
    }

    pub fn pooled(threads: usize) -> GridScheduler {
        GridScheduler { threads: threads.max(1) }
    }

    /// Execute `program` over the whole grid.
    ///
    /// `inputs` are the non-output parameters in order; outputs are
    /// allocated as zero-filled f32 tensors of `output_shapes` and
    /// returned in parameter order.
    pub fn run(
        &self,
        program: &TileProgram,
        views: &[ParamView],
        inputs: &[&HostTensor],
        output_shapes: &[Vec<usize>],
    ) -> Result<Vec<HostTensor>> {
        self.run_with(program, views, inputs, output_shapes, None)
    }

    /// [`GridScheduler::run`] with an optional [`ProfileReport`]: when
    /// present and enabled, per-instruction and per-cell wall time is
    /// accumulated into it (the report is `Sync` — grid workers record
    /// concurrently).
    pub fn run_with(
        &self,
        program: &TileProgram,
        views: &[ParamView],
        inputs: &[&HostTensor],
        output_shapes: &[Vec<usize>],
        profile: Option<&ProfileReport>,
    ) -> Result<Vec<HostTensor>> {
        // marshal parameter data: inputs in declaration order, outputs
        // allocated here
        let is_output: Vec<bool> = views.iter().map(|v| v.is_output).collect();
        program.validate(views.len(), &is_output)?;
        let n_inputs = views.iter().filter(|v| !v.is_output).count();
        if inputs.len() != n_inputs {
            bail!("program {} expects {} inputs, got {}", program.name, n_inputs, inputs.len());
        }
        let grid = views
            .first()
            .map(|v| v.grid.clone())
            .ok_or_else(|| anyhow!("program {} has no parameters", program.name))?;
        for v in views {
            if v.grid != grid {
                bail!(
                    "outermost-level shapes disagree: {:?} ({}) vs {grid:?} — invalid \
                     arrangement (paper §3.2.1)",
                    v.grid,
                    v.name
                );
            }
        }
        // the loop (sub-tile) shape shared by looped parameters
        let mut loop_shape: Vec<usize> = Vec::new();
        for v in views {
            if !v.loop_shape.is_empty() {
                if loop_shape.is_empty() {
                    loop_shape = v.loop_shape.clone();
                } else if loop_shape != v.loop_shape {
                    bail!(
                        "loop-level shapes disagree: {:?} ({}) vs {loop_shape:?}",
                        v.loop_shape,
                        v.name
                    );
                }
            }
        }

        let mut outputs: Vec<HostTensor> = Vec::new();
        {
            let mut shapes = output_shapes.iter();
            for v in views {
                if v.is_output {
                    let shape = shapes
                        .next()
                        .ok_or_else(|| anyhow!("missing output shape for {}", v.name))?;
                    // the scatter bounds-check uses the view's src_shape,
                    // so the buffer MUST match it — the raw-pointer write
                    // below is only sound under this equality
                    if shape != &v.src_shape {
                        bail!(
                            "output shape {shape:?} for {} does not match its view's \
                             source shape {:?}",
                            v.name,
                            v.src_shape
                        );
                    }
                    outputs.push(HostTensor::zeros_f32(shape.clone()));
                }
            }
        }
        let data: Vec<ParamData<'_>> = {
            let mut ins = inputs.iter().copied();
            views
                .iter()
                .map(|v| {
                    if v.is_output {
                        ParamData::Out
                    } else {
                        ParamData::In(ins.next().expect("input arity checked above"))
                    }
                })
                .collect()
        };

        let cells: i64 = grid.iter().product::<i64>().max(1);
        let out_ptrs: Vec<SharedOut> = outputs
            .iter_mut()
            .map(|t| match &mut t.data {
                crate::runtime::HostData::F32(v) => SharedOut(v.as_mut_ptr(), v.len()),
                crate::runtime::HostData::I32(_) => unreachable!("outputs are f32"),
            })
            .collect();

        // parallel writes are sound only if distinct cells scatter to
        // disjoint offsets: for every output view and every non-trivial
        // grid dimension, some source dim's cell stride must clear the
        // whole window one cell writes (an expanded grid dim — or a
        // sliding-window stride smaller than the tile — would make cells
        // along it write overlapping elements concurrently)
        for v in views.iter().filter(|v| v.is_output) {
            for (g, &size) in grid.iter().enumerate() {
                if size > 1 && !v.grid_dim_disjoint(g) {
                    bail!(
                        "output parameter {} writes overlapping regions across grid \
                         dim {g} (size {size}) — invalid arrangement for parallel \
                         execution (paper §3.2.1 non-overlap)",
                        v.name
                    );
                }
            }
        }

        // below ~2 cells per worker the spawn/join cost dominates: run
        // the grid on the caller's thread and hand the whole pool to each
        // cell instead — heavy intra-tile work (a `DotAcc` on a big
        // single-tile GEMM) then row-splits across the pool, while cheap
        // programs ignore the budget entirely
        let (threads, intra) = if (cells as usize) < self.threads.saturating_mul(2) {
            (1, self.threads)
        } else {
            (self.threads, 1)
        };
        if threads == 1 {
            run_cells(
                program, views, &data, &grid, &loop_shape, 0, cells, intra, profile, &out_ptrs,
            )?;
        } else {
            let failure: Mutex<Option<anyhow::Error>> = Mutex::new(None);
            let chunk = (cells + threads as i64 - 1) / threads as i64;
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
            for t in 0..threads {
                let (data, failure) = (&data, &failure);
                let (grid, loop_shape, out_ptrs) = (&grid, &loop_shape, &out_ptrs);
                let lo = t as i64 * chunk;
                let hi = (lo + chunk).min(cells);
                if lo >= hi {
                    continue;
                }
                tasks.push(Box::new(move || {
                    if let Err(e) = run_cells(
                        program, views, data, grid, loop_shape, lo, hi, intra, profile, out_ptrs,
                    ) {
                        *failure.lock().unwrap() = Some(e);
                    }
                }));
            }
            super::pool::global().run_scoped(tasks);
            if let Some(e) = failure.into_inner().unwrap() {
                return Err(e);
            }
        }
        Ok(outputs)
    }
}

#[allow(clippy::too_many_arguments)]
fn run_cells(
    program: &TileProgram,
    views: &[ParamView],
    data: &[ParamData<'_>],
    grid: &[i64],
    loop_shape: &[usize],
    lo: i64,
    hi: i64,
    intra_threads: usize,
    profile: Option<&ProfileReport>,
    out_ptrs: &[SharedOut],
) -> Result<()> {
    let out_index: Vec<Option<usize>> = {
        let mut next = 0usize;
        views
            .iter()
            .map(|v| {
                if v.is_output {
                    next += 1;
                    Some(next - 1)
                } else {
                    None
                }
            })
            .collect()
    };
    let mut cell = vec![0i64; grid.len()];
    let mut write = |param: usize, off: usize, v: f32| {
        let SharedOut(ptr, len) = out_ptrs[out_index[param].expect("store targets an output")];
        debug_assert!(off < len, "scatter offset {off} out of range {len}");
        // SAFETY: distinct grid cells write disjoint offsets — §3.2.1
        // non-overlap, enforced by the output-disjointness check in
        // `GridScheduler::run` before any thread is spawned; `ptr`
        // outlives the scope and `off < len` by scatter bounds-checking.
        unsafe { *ptr.add(off) = v };
    };
    let prof = profile.filter(|p| p.is_enabled());
    for linear in lo..hi {
        // linear → multi-index (row-major)
        let mut rem = linear;
        for d in (0..grid.len()).rev() {
            cell[d] = rem % grid[d].max(1);
            rem /= grid[d].max(1);
        }
        let t0 = prof.map(|_| std::time::Instant::now());
        exec_cell(program, views, data, &cell, loop_shape, intra_threads, profile, &mut write)?;
        if let (Some(p), Some(t0)) = (prof, t0) {
            p.record_cell(t0.elapsed().as_nanos() as u64);
        }
    }
    Ok(())
}
