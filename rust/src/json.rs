//! Minimal JSON parser/serializer (no serde in the offline crate set).
//!
//! Supports the full JSON grammar the AOT manifest uses: objects, arrays,
//! strings (with escapes), integers, floats, booleans, null.  Numbers are
//! kept as `f64` with an exact-integer accessor, which is lossless for all
//! sizes/offsets the manifest contains (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key {key:?} in JSON object"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not an array"))
    }

    pub fn str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not a string"))
    }

    pub fn usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not a usize"))
    }

    pub fn f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not a number"))
    }

    pub fn usize_vec(&self, key: &str) -> anyhow::Result<Vec<usize>> {
        self.arr(key)?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("non-usize in {key:?}")))
            .collect()
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_nan() {
                    // mirror the tokens the parser accepts (Python's json
                    // emits these); Rust's own Display would print "NaN"
                    // for NaN but "inf" for infinities, which no JSON
                    // parser — including ours — reads back
                    write!(f, "NaN")
                } else if n.is_infinite() {
                    write!(f, "{}Infinity", if *n < 0.0 { "-" } else { "" })
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected literal {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            // Python's json module emits these nonstandard tokens for
            // float('inf')/float('nan') (e.g. softmax's -inf pad value)
            Some(b'I') => self.literal("Infinity", Json::Num(f64::INFINITY)),
            Some(b'N') => self.literal("NaN", Json::Num(f64::NAN)),
            Some(b'-') if self.bytes.get(self.pos + 1) == Some(&b'I') => {
                self.pos += 1;
                self.literal("Infinity", Json::Num(f64::NEG_INFINITY))
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| self.err("truncated utf-8"))?;
                    out.push_str(std::str::from_utf8(chunk).map_err(|_| self.err("bad utf-8"))?);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.arr("a").unwrap().len(), 3);
        assert_eq!(v.arr("a").unwrap()[2].str("b").unwrap(), "c");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"a":[1,2.5,"x"],"b":true,"c":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn python_nonstandard_floats() {
        assert_eq!(Json::parse("-Infinity").unwrap(), Json::Num(f64::NEG_INFINITY));
        assert_eq!(Json::parse("Infinity").unwrap(), Json::Num(f64::INFINITY));
        assert!(matches!(Json::parse("NaN").unwrap(), Json::Num(v) if v.is_nan()));
    }

    #[test]
    fn nonfinite_floats_roundtrip_through_display() {
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "Infinity");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "-Infinity");
        assert_eq!(Json::Num(f64::NAN).to_string(), "NaN");
        for v in [f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::parse(&Json::Num(v).to_string()).unwrap(), Json::Num(v));
        }
        assert!(matches!(
            Json::parse(&Json::Num(f64::NAN).to_string()).unwrap(),
            Json::Num(v) if v.is_nan()
        ));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }
}
