//! Benchmark kit: timing statistics and reporting (the offline crate set
//! has no criterion; `cargo bench` targets use this with `harness = false`).

use std::time::{Duration, Instant};

/// Summary statistics over a sample of run times.
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
}

impl Stats {
    pub fn from_samples(samples: &[Duration]) -> Stats {
        assert!(!samples.is_empty());
        let mut secs: Vec<f64> = samples.iter().map(Duration::as_secs_f64).collect();
        secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = secs.len();
        let mean = secs.iter().sum::<f64>() / n as f64;
        let var = secs.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n as f64;
        Stats {
            n,
            mean_s: mean,
            median_s: secs[n / 2],
            p95_s: secs[(n * 95 / 100).min(n - 1)],
            min_s: secs[0],
            max_s: secs[n - 1],
            stddev_s: var.sqrt(),
        }
    }

    pub fn throughput(&self, units: f64) -> f64 {
        units / self.mean_s
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    Stats::from_samples(&samples)
}

/// Adaptive variant: run until `min_time` has elapsed (at least 3 iters).
pub fn bench_for<F: FnMut()>(warmup: usize, min_time: Duration, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < 3 || start.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 1000 {
            break;
        }
    }
    Stats::from_samples(&samples)
}

pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Simple fixed-width table printer for harness reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let body: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect();
            format!("| {} |\n", body.join(" | "))
        };
        out.push_str(&line(&self.headers, &widths));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&line(&sep, &widths));
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let samples = vec![
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        ];
        let s = Stats::from_samples(&samples);
        assert_eq!(s.n, 3);
        assert!((s.mean_s - 0.020).abs() < 1e-9);
        assert_eq!(s.min_s, 0.010);
        assert_eq!(s.max_s, 0.030);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("| a | bb |"));
        assert!(r.contains("| 1 |  2 |"));
    }
}
