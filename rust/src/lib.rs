//! ninetoothed-repro: the L3 Rust coordinator of the NineToothed
//! (Huang et al., 2025) reproduction.
//!
//! The paper's contribution is a kernel-authoring DSL (L1/L2, in
//! `python/compile/`); this crate is everything around it that makes the
//! result a deployable system and regenerates the paper's evaluation:
//!
//! * [`symbolic`] / [`tensor`] / [`arrange`] — a full Rust mirror of the
//!   DSL's tensor-oriented metaprogramming algebra, used to validate
//!   arrangements and compute launch plans at serve time;
//! * [`kernel`] — the paper's `make(arrangement, application, tensors)`
//!   API as a first-class Rust surface: kernels are *declared* (symbolic
//!   tensors + catalog arrangement + a tile program authored through a
//!   typed builder), and arity, shape preconditions, output inference,
//!   the per-shape specializer and coalescibility are all **derived**;
//!   definitions live in the global [`kernel::KernelRegistry`] the whole
//!   serving stack resolves through;
//! * [`exec`] — the **native tile-execution backend**, an explicit
//!   compile → cache → execute pipeline: a tile-program IR mirroring the
//!   `ntl` operation set, strided tile views lowered once per shape
//!   signature into plan-cached [`exec::CompiledProgram`]s, and a grid
//!   scheduler dispatching onto one persistent worker pool;
//! * [`runtime`] — execution backends behind the
//!   [`runtime::Backend`] trait's `prepare`/`execute` split: PJRT/AOT
//!   artifact loading plus the native fallback, unified in the executable
//!   [`runtime::Registry`] (artifact when present, native tile program
//!   otherwise) over a shared plan cache;
//! * [`coordinator`] — the kernel-serving system: router, dynamic batcher
//!   (slot packing + native same-shape coalescing), worker pool, metrics.
//!   Requests for kernels without artifacts are routed to the native
//!   backend transparently;
//! * [`obs`] — the observability layer threaded through the stack: a
//!   per-kernel/per-shape [`obs::MetricsRegistry`], a sampled request
//!   [`obs::TraceRecorder`] with a waterfall renderer, and an opt-in
//!   (`NT_PROFILE=1`) per-instruction/per-cell execution profiler; one
//!   [`obs::ObsSnapshot`] exports all of it as a human table
//!   (`repro stats`), Prometheus exposition text, or JSON;
//! * [`inference`] — the end-to-end autoregressive engine of Fig 7;
//! * [`codemetrics`] — the Table 2 metric suite (raw, cyclomatic, Halstead,
//!   maintainability index) over Python kernel sources;
//! * [`harness`] — regenerates every table and figure of the paper's
//!   evaluation section;
//! * [`json`] / [`prng`] / [`benchkit`] / [`cli`] — dependency-free
//!   infrastructure (the offline crate set contains only in-tree path
//!   crates; see `vendor/`).

pub mod arrange;
pub mod benchkit;
pub mod cli;
pub mod codemetrics;
pub mod coordinator;
pub mod exec;
pub mod harness;
pub mod inference;
pub mod json;
pub mod kernel;
pub mod obs;
pub mod prng;
pub mod runtime;
pub mod symbolic;
pub mod tensor;

use std::path::PathBuf;

/// Locate the artifacts directory (env override, then target-relative).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("NT_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // walk up from cwd until an `artifacts/manifest.json` is found
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let candidate = dir.join("artifacts");
        if candidate.join("manifest.json").exists() {
            return candidate;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}
