//! Rust mirror of the hierarchical symbolic tensor and the meta-operations
//! of paper Table 1 (`python/compile/ninetoothed/tensor.py`).
//!
//! The coordinator uses this to re-derive arrangements independently of the
//! Python DSL: the ten paper arrangements are re-expressed in Rust
//! (`crate::arrange::catalog`) and cross-checked against the manifest
//! metadata the AOT step exported — a structural regression test that the
//! two implementations of the algebra agree.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::symbolic::Expr;

/// One dimension of one level: a size expression plus its index variable.
#[derive(Debug, Clone)]
pub struct Dim {
    pub size: Expr,
    pub var: String,
}

/// A hierarchical symbolic tensor (levels + per-source-dim index exprs).
#[derive(Debug, Clone)]
pub struct SymTensor {
    pub name: String,
    pub source_ndim: usize,
    /// level 0 is outermost; the innermost level is the application tile
    pub levels: Vec<Vec<Dim>>,
    /// source-to-target mapping: one expression per source dimension
    pub indices: Vec<Expr>,
    /// expressions that must evaluate to 1 at specialization time
    pub checks: Vec<Expr>,
    /// which level "dtype views" operate on
    level_offset: usize,
    counter: u64,
}

impl SymTensor {
    pub fn new(name: &str, ndim: usize) -> SymTensor {
        let mut t = SymTensor {
            name: name.to_string(),
            source_ndim: ndim,
            levels: vec![Vec::new()],
            indices: Vec::new(),
            checks: Vec::new(),
            level_offset: 0,
            counter: 0,
        };
        for d in 0..ndim {
            let var = t.fresh(&format!("{name}{d}"));
            t.levels[0].push(Dim { size: Expr::sym(&format!("{name}_size_{d}")), var: var.clone() });
            t.indices.push(Expr::sym(&var));
        }
        t
    }

    fn fresh(&mut self, prefix: &str) -> String {
        self.counter += 1;
        format!("_rs_{}_{}_{}", self.name, prefix, self.counter)
    }

    pub fn shape(&self) -> Vec<Expr> {
        self.levels[self.level_offset].iter().map(|d| d.size.clone()).collect()
    }

    /// A view one level down (the paper's `t.dtype`).
    pub fn dtype(&self) -> SymTensor {
        let mut v = self.clone();
        v.level_offset += 1;
        assert!(v.level_offset < v.levels.len(), "dtype view past innermost level");
        v
    }

    /// The paper's `t.dtype = view` assignment.
    pub fn set_dtype(&mut self, view: SymTensor) {
        self.levels = view.levels;
        self.indices = view.indices;
        self.checks = view.checks;
        self.counter = self.counter.max(view.counter);
    }

    fn substitute_indices(&mut self, mapping: &BTreeMap<String, Expr>) {
        for e in &mut self.indices {
            *e = e.substitute(mapping);
        }
    }

    // -- meta-operations -------------------------------------------------------

    /// `tile(tile_shape, strides)`; `None` entries mean -1 (defaults).
    pub fn tile(&self, tile_shape: &[Option<Expr>], strides: Option<&[Option<Expr>]>) -> Result<SymTensor> {
        let current = self.levels[self.level_offset].clone();
        if tile_shape.len() != current.len() {
            bail!("tile shape rank {} != level rank {}", tile_shape.len(), current.len());
        }
        let mut out = self.clone();
        let mut outer = Vec::new();
        let mut inner = Vec::new();
        let mut mapping = BTreeMap::new();
        for (i, dim) in current.iter().enumerate() {
            let t = tile_shape[i].clone().unwrap_or_else(|| dim.size.clone());
            let s = strides
                .and_then(|ss| ss[i].clone())
                .unwrap_or_else(|| t.clone());
            let outer_size = if s == t {
                Expr::cdiv(dim.size.clone(), t.clone())
            } else {
                Expr::add(
                    Expr::floordiv(Expr::sub(dim.size.clone(), t.clone()), s.clone()),
                    Expr::Const(1),
                )
            };
            let ov = out.fresh("o");
            let iv = out.fresh("t");
            mapping.insert(
                dim.var.clone(),
                Expr::add(Expr::mul(Expr::sym(&ov), s), Expr::sym(&iv)),
            );
            outer.push(Dim { size: outer_size, var: ov });
            inner.push(Dim { size: t, var: iv });
        }
        let off = out.level_offset;
        out.levels.splice(off..off + 1, [outer, inner]);
        out.substitute_indices(&mapping);
        Ok(out)
    }

    /// `expand(shape)`; `None` entries mean -1 (keep).
    pub fn expand(&self, shape: &[Option<Expr>]) -> Result<SymTensor> {
        let current = self.levels[self.level_offset].clone();
        if shape.len() != current.len() {
            bail!("expand rank mismatch");
        }
        let mut out = self.clone();
        let mut dims = Vec::new();
        let mut mapping = BTreeMap::new();
        for (dim, new_size) in current.iter().zip(shape) {
            match new_size {
                None => dims.push(dim.clone()),
                Some(size) => {
                    match dim.size.constant() {
                        Some(1) => {}
                        Some(_) => bail!("cannot expand non-singleton dim {}", dim.size),
                        None => out.checks.push(dim.size.clone()),
                    }
                    mapping.insert(dim.var.clone(), Expr::Const(0));
                    let var = out.fresh("e");
                    dims.push(Dim { size: size.clone(), var });
                }
            }
        }
        out.levels[self.level_offset] = dims;
        out.substitute_indices(&mapping);
        Ok(out)
    }

    pub fn squeeze(&self, dims: &[i64]) -> Result<SymTensor> {
        let current = self.levels[self.level_offset].clone();
        let n = current.len() as i64;
        let mut drop: Vec<usize> = dims
            .iter()
            .map(|&d| {
                let d = if d < 0 { d + n } else { d };
                usize::try_from(d).ok().filter(|&d| d < current.len())
            })
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow::anyhow!("squeeze dim out of range"))?;
        drop.sort_unstable();
        let mut out = self.clone();
        let mut kept = Vec::new();
        let mut mapping = BTreeMap::new();
        for (i, dim) in current.iter().enumerate() {
            if drop.contains(&i) {
                match dim.size.constant() {
                    Some(1) => {}
                    Some(_) => bail!("cannot squeeze dim of size {}", dim.size),
                    None => out.checks.push(dim.size.clone()),
                }
                mapping.insert(dim.var.clone(), Expr::Const(0));
            } else {
                kept.push(dim.clone());
            }
        }
        out.levels[self.level_offset] = kept;
        out.substitute_indices(&mapping);
        Ok(out)
    }

    pub fn unsqueeze(&self, dim: i64) -> Result<SymTensor> {
        let current = self.levels[self.level_offset].clone();
        let n = current.len() as i64 + 1;
        let d = if dim < 0 { dim + n } else { dim };
        let d = usize::try_from(d)
            .ok()
            .filter(|&d| d <= current.len())
            .ok_or_else(|| anyhow::anyhow!("unsqueeze dim out of range"))?;
        let mut out = self.clone();
        let var = out.fresh("u");
        out.levels[self.level_offset].insert(d, Dim { size: Expr::Const(1), var });
        Ok(out)
    }

    pub fn permute(&self, order: &[usize]) -> Result<SymTensor> {
        let current = self.levels[self.level_offset].clone();
        let mut sorted = order.to_vec();
        sorted.sort_unstable();
        if sorted != (0..current.len()).collect::<Vec<_>>() {
            bail!("invalid permutation {order:?}");
        }
        let mut out = self.clone();
        out.levels[self.level_offset] = order.iter().map(|&d| current[d].clone()).collect();
        Ok(out)
    }

    /// `flatten(start, end)` with Python-slice (exclusive-end) semantics.
    pub fn flatten(&self, start: usize, end: Option<usize>) -> Result<SymTensor> {
        let current = self.levels[self.level_offset].clone();
        let end = end.unwrap_or(current.len());
        if !(start < end && end <= current.len()) {
            bail!("invalid flatten range [{start}, {end})");
        }
        let merged = &current[start..end];
        let mut total = merged[0].size.clone();
        for d in &merged[1..] {
            total = Expr::mul(total, d.size.clone());
        }
        let mut out = self.clone();
        let fv = out.fresh("f");
        let w = Expr::sym(&fv);
        let mut mapping = BTreeMap::new();
        let mut trailing = Expr::Const(1);
        for d in merged.iter().rev() {
            let component = if trailing == Expr::Const(1) {
                Expr::modulo(w.clone(), d.size.clone())
            } else {
                Expr::modulo(Expr::floordiv(w.clone(), trailing.clone()), d.size.clone())
            };
            mapping.insert(d.var.clone(), component);
            trailing = Expr::mul(trailing, d.size.clone());
        }
        // the outermost merged dim needs no modulo
        let first = &merged[0];
        let rest = Expr::floordiv(trailing.clone(), first.size.clone());
        let top = if rest == Expr::Const(1) {
            w.clone()
        } else {
            Expr::floordiv(w.clone(), rest)
        };
        mapping.insert(first.var.clone(), top);

        let mut dims = current[..start].to_vec();
        dims.push(Dim { size: total, var: fv });
        dims.extend_from_slice(&current[end..]);
        out.levels[self.level_offset] = dims;
        out.substitute_indices(&mapping);
        Ok(out)
    }

    /// `ravel()`: collapse all levels (from the view level down) into one.
    pub fn ravel(&self) -> SymTensor {
        let mut out = self.clone();
        let off = out.level_offset;
        let merged: Vec<Dim> = out.levels[off..].iter().flatten().cloned().collect();
        out.levels.truncate(off);
        out.levels.push(merged);
        out
    }

    // -- launch-plan computation -------------------------------------------------

    /// Verify every deferred expand/squeeze check evaluates to 1 under the
    /// bindings (symbolic size-1 dims are only provable at specialization
    /// time).  The native exec backend calls this before lowering.
    pub fn validate_checks(&self, bindings: &BTreeMap<String, i64>) -> Result<()> {
        for check in &self.checks {
            let v = check.substitute_consts(bindings).eval(bindings)?;
            if v != 1 {
                bail!(
                    "parameter {}: expand/squeeze check {check} = {v}, expected 1",
                    self.name
                );
            }
        }
        Ok(())
    }

    /// Evaluate the outermost-level shape (the grid) under bindings.
    pub fn grid(&self, bindings: &BTreeMap<String, i64>) -> Result<Vec<i64>> {
        self.levels[0]
            .iter()
            .map(|d| Ok(d.size.substitute_consts(bindings).eval(bindings)?))
            .collect()
    }

    /// Padded extent per source dim (interval arithmetic over index exprs),
    /// mirroring `_ParamSpec` in generation.py.
    pub fn padded_extents(&self, bindings: &BTreeMap<String, i64>) -> Result<Vec<i64>> {
        let mut ranges: BTreeMap<String, (i64, i64)> = BTreeMap::new();
        for level in &self.levels {
            for dim in level {
                let size = dim.size.substitute_consts(bindings).eval(bindings)?;
                ranges.insert(dim.var.clone(), (0, (size - 1).max(0)));
            }
        }
        for (k, v) in bindings {
            ranges.insert(k.clone(), (*v, *v));
        }
        self.indices
            .iter()
            .map(|e| {
                let (_, hi) = e.bounds(&ranges)?;
                Ok(hi + 1)
            })
            .collect()
    }
}

impl Expr {
    /// Substitute integer bindings (helper bridging `BTreeMap<String, i64>`).
    pub fn substitute_consts(&self, bindings: &BTreeMap<String, i64>) -> Expr {
        let env: BTreeMap<String, Expr> = bindings
            .iter()
            .map(|(k, v)| (k.clone(), Expr::Const(*v)))
            .collect();
        self.substitute(&env)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn b(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn tile_produces_hierarchy() {
        let x = SymTensor::new("x", 2);
        let t = x.tile(&[Some(Expr::Const(16)), Some(Expr::Const(32))], None).unwrap();
        assert_eq!(t.levels.len(), 2);
        let g = t.grid(&b(&[("x_size_0", 100), ("x_size_1", 64)])).unwrap();
        assert_eq!(g, vec![7, 2]);
    }

    #[test]
    fn tile_index_coverage() {
        // every source element covered exactly once (paper's non-overlap default)
        let x = SymTensor::new("x", 1);
        let t = x.tile(&[Some(Expr::Const(4))], None).unwrap();
        let expr = &t.indices[0];
        let (outer, inner) = (&t.levels[0][0], &t.levels[1][0]);
        let mut seen = std::collections::BTreeSet::new();
        for o in 0..3 {
            for i in 0..4 {
                let mut env = b(&[("x_size_0", 10)]);
                env.insert(outer.var.clone(), o);
                env.insert(inner.var.clone(), i);
                let v = expr.eval(&env).unwrap();
                assert!(seen.insert(v), "duplicate coverage of {v}");
            }
        }
        assert!((0..10).all(|v| seen.contains(&v)));
    }

    #[test]
    fn conv_tile_strides() {
        // tile((3,), strides=(1,)) — overlapping windows
        let x = SymTensor::new("x", 1);
        let t = x
            .tile(&[Some(Expr::Const(3))], Some(&[Some(Expr::Const(1))]))
            .unwrap();
        let g = t.grid(&b(&[("x_size_0", 10)])).unwrap();
        assert_eq!(g, vec![8]); // 10 - 3 + 1
    }

    #[test]
    fn expand_is_broadcast() {
        let x = SymTensor::new("x", 2);
        let t = x.tile(&[Some(Expr::Const(4)), None], None).unwrap();
        let e = t.expand(&[None, Some(Expr::sym("N"))]).unwrap();
        // expanded var does not appear in the index expressions
        let frees: std::collections::BTreeSet<String> =
            e.indices.iter().flat_map(|i| i.free_symbols()).collect();
        let expanded_var = &e.levels[0][1].var;
        assert!(!frees.contains(expanded_var));
    }

    #[test]
    fn flatten_bijection() {
        let x = SymTensor::new("x", 3);
        let f = x.flatten(0, None).unwrap();
        let var = f.levels[0][0].var.clone();
        let sizes = b(&[("x_size_0", 2), ("x_size_1", 4), ("x_size_2", 5)]);
        let mut seen = std::collections::BTreeSet::new();
        for w in 0..40 {
            let mut env = sizes.clone();
            env.insert(var.clone(), w);
            let coords: Vec<i64> = f.indices.iter().map(|e| e.eval(&env).unwrap()).collect();
            assert!(seen.insert(coords.clone()));
            assert!(coords[0] < 2 && coords[1] < 4 && coords[2] < 5);
        }
        assert_eq!(seen.len(), 40);
    }

    #[test]
    fn padded_extents_cover_reads() {
        let x = SymTensor::new("x", 1);
        let t = x.tile(&[Some(Expr::sym("B"))], None).unwrap();
        let ext = t.padded_extents(&b(&[("x_size_0", 10), ("B", 4)])).unwrap();
        assert_eq!(ext, vec![12]); // 3 tiles of 4
    }

    #[test]
    fn dtype_view_roundtrip() {
        let mut x = SymTensor::new("x", 2)
            .tile(&[Some(Expr::Const(1)), Some(Expr::Const(16))], None)
            .unwrap();
        let squeezed = x.dtype().squeeze(&[0]).unwrap();
        x.set_dtype(squeezed);
        assert_eq!(x.levels[1].len(), 1);
    }
}
