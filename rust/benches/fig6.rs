//! `cargo bench --bench fig6` — regenerates paper Fig 6 (single-kernel
//! performance, NineToothed vs hand-written baseline vs jnp reference).

use std::sync::Arc;
use std::time::Duration;

use ninetoothed_repro::harness::fig6;
use ninetoothed_repro::runtime::{Manifest, Registry, Runtime};

fn main() {
    let manifest = Arc::new(Manifest::load(&ninetoothed_repro::artifacts_dir()).expect("manifest"));
    let registry = Registry::new(Runtime::cpu().expect("pjrt"), manifest);
    let secs = std::env::var("NT_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2u64);
    println!(
        "Fig 6 bench ({} scale, >= {secs}s per measurement)",
        if registry.manifest().full { "paper" } else { "scaled" }
    );
    let results = fig6::run_all(&registry, Duration::from_secs(secs)).expect("fig6");
    println!("{}", fig6::report(&results));
}
