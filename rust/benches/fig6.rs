//! `cargo bench --bench fig6` — regenerates paper Fig 6 (single-kernel
//! performance, NineToothed vs hand-written baseline vs jnp reference).

use std::sync::Arc;
use std::time::Duration;

use ninetoothed_repro::harness::fig6;
use ninetoothed_repro::runtime::{Manifest, Registry, Runtime};

fn main() {
    let manifest = match Manifest::load(&ninetoothed_repro::artifacts_dir()) {
        Ok(m) => Arc::new(m),
        Err(e) => {
            println!("skipping fig6 bench (requires `make artifacts`): {e:#}");
            return;
        }
    };
    let runtime = match Runtime::cpu() {
        Ok(r) => r,
        Err(e) => {
            println!("skipping fig6 bench (requires a PJRT runtime): {e:#}");
            return;
        }
    };
    let registry = Registry::new(runtime, manifest);
    let secs = std::env::var("NT_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2u64);
    println!(
        "Fig 6 bench ({} scale, >= {secs}s per measurement)",
        if registry.manifest().full { "paper" } else { "scaled" }
    );
    let results = fig6::run_all(&registry, Duration::from_secs(secs)).expect("fig6");
    println!("{}", fig6::report(&results));
}
