//! `cargo bench --bench ablations` — design-choice ablations called out in
//! DESIGN.md §8:
//!
//! 1. **slot packing on/off** — coordinator throughput on a burst of
//!    variable-length element-wise requests with max_fanin 1 vs 16;
//! 2. **weight re-serialization** — decode-step latency when weights are
//!    rebuilt per step vs passed by reference (the Engine's design);
//! 3. **block-size sweep** — NT mm artifacts are shape-specialized, so the
//!    sweep reports launch-plan geometry (programs, VMEM/program) from the
//!    Rust algebra for candidate block sizes — the structural quantity a
//!    real-TPU tuning pass would optimize.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use ninetoothed_repro::arrange::catalog;
use ninetoothed_repro::coordinator::{Coordinator, CoordinatorConfig};
use ninetoothed_repro::inference::Engine;
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{HostTensor, Manifest, Registry, Runtime};

fn main() {
    let manifest = match Manifest::load(&ninetoothed_repro::artifacts_dir()) {
        Ok(m) => Arc::new(m),
        Err(e) => {
            // graceful skip: this bench measures the artifact path, which
            // needs `make artifacts` + a PJRT runtime
            println!("skipping ablations bench: {e:#}");
            return;
        }
    };

    // --- ablation 1: slot packing ------------------------------------------
    println!("== ablation 1: slot packing (coordinator, 48 add requests) ==");
    let slot = manifest.kernel("add", "nt").expect("add").args[0].shape[0];
    for (label, fanin) in [("packing OFF (fanin=1)", 1), ("packing ON (fanin=16)", 16)] {
        let coordinator = Coordinator::start(
            manifest.clone(),
            CoordinatorConfig {
                workers: 1,
                queue_capacity: 4096,
                max_fanin: fanin,
                ..Default::default()
            },
        )
        .expect("start coordinator");
        let mut rng = SplitMix64::new(5);
        let warm = HostTensor::randn(vec![slot], &mut rng);
        coordinator
            .submit("add", "nt", vec![warm.clone(), warm])
            .expect("warm")
            .recv()
            .expect("recv")
            .expect("warm resp");
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for _ in 0..48 {
            let n = 1024 + rng.below((slot / 12) as u64) as usize;
            let x = HostTensor::randn(vec![n], &mut rng);
            let y = HostTensor::randn(vec![n], &mut rng);
            rxs.push(coordinator.submit("add", "nt", vec![x, y]).expect("submit"));
        }
        for rx in rxs {
            rx.recv().expect("recv").expect("resp");
        }
        let elapsed = t0.elapsed();
        let metrics = coordinator.metrics();
        println!(
            "  {label:<22} wall {elapsed:>9.1?}  executions={} batching={:.2}x",
            metrics.executions, metrics.batching_factor()
        );
        coordinator.shutdown();
    }

    // --- ablation 2: weight passing in the decode loop -----------------------
    println!("\n== ablation 2: decode-step weight handling (8 steps) ==");
    let registry = match Runtime::cpu() {
        Ok(runtime) => Arc::new(Registry::new(runtime, manifest.clone())),
        Err(e) => {
            println!("skipping ablations 2-3: no PJRT runtime ({e:#})");
            return;
        }
    };
    let engine = Engine::new(registry, "ref").expect("engine");
    let prompt = engine.synth_prompt(3);
    engine.generate(&prompt, 4).expect("warm");
    let t0 = Instant::now();
    let result = engine.generate(&prompt, 8).expect("by-reference run");
    println!(
        "  weights by reference   decode {:?} ({:.2} tok/s end-to-end)",
        result.decode_time, result.tokens_per_s
    );
    println!("  (re-serializing weights per step was removed in the perf pass — see EXPERIMENTS.md §Perf)");

    // --- ablation 3: mm block-size sweep (launch-plan geometry) --------------
    println!("\n== ablation 3: mm block-size sweep (structural, Rust algebra) ==");
    let tensors = catalog::mm().expect("mm catalog");
    let (m, k, n) = (4096i64, 4096i64, 4096i64);
    println!("  problem: {m}x{k} @ {k}x{n} (paper scale)");
    for block in [32i64, 64, 128, 256] {
        let mut env: BTreeMap<String, i64> = BTreeMap::new();
        for (key, value) in [
            ("BLOCK_SIZE_M", block), ("BLOCK_SIZE_N", block), ("BLOCK_SIZE_K", block),
            ("input_size_0", m), ("input_size_1", k),
            ("other_size_0", k), ("other_size_1", n),
            ("output_size_0", m), ("output_size_1", n),
        ] {
            env.insert(key.to_string(), value);
        }
        let (grid, _) = catalog::geometry(&tensors, &env).expect("geometry");
        let programs: i64 = grid.iter().product();
        // per-program tiles: A (bm x bk) strip over K, B strip, C tile
        let vmem_bytes = (block * block * 4) * 3;
        let flops_per_program = 2 * block * block * k;
        println!(
            "  block {block:>3}: grid {grid:?} -> {programs:>5} programs, \
             ~{:>6} KiB VMEM/program, {:>7.1} MFLOP/program",
            vmem_bytes / 1024,
            flops_per_program as f64 / 1e6
        );
    }
    println!("  (128 is the MXU-native tile; DESIGN.md §8 discusses the real-TPU choice)");
}
