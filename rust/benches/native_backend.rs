//! `cargo bench --bench native_backend` — native tile-execution backend
//! throughput: single-thread vs pooled grid scheduler, and (when
//! artifacts + a PJRT runtime exist) vs the AOT artifact path.
//!
//! Emits a `BENCH_native.json` report next to the working directory with
//! one row per (kernel, scheduler): mean latency, GFLOP/s, and the pooled
//! speedup over serial — the scaling evidence that the grid scheduler
//! actually parallelizes (ISSUE 1 acceptance).
//!
//! Environment: `NT_BENCH_SECS` (min seconds per measurement, default 1),
//! `NT_BENCH_THREADS` (pool width, default = available parallelism).

use std::collections::BTreeMap;
use std::time::Duration;

use ninetoothed_repro::benchkit::{bench_for, fmt_duration, Table};
use ninetoothed_repro::exec::{self, GridScheduler};
use ninetoothed_repro::json::Json;
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{HostTensor, Manifest, Registry, Runtime};

struct Case {
    kernel: &'static str,
    inputs: Vec<HostTensor>,
    flops: f64,
}

fn cases(rng: &mut SplitMix64) -> Vec<Case> {
    // debug builds (cargo test runs bench targets under the dev profile)
    // use smaller problems; real numbers come from `cargo bench` (release)
    let (mm, bmm, add_n, sm) = if cfg!(debug_assertions) {
        ((192usize, 192usize, 192usize), (4usize, 64usize, 64usize, 64usize), 1_000_000usize, (64usize, 1024usize))
    } else {
        ((384, 384, 384), (8, 128, 128, 128), 4_000_000, (256, 2048))
    };
    vec![
        Case {
            kernel: "add",
            inputs: vec![
                HostTensor::randn(vec![add_n], rng),
                HostTensor::randn(vec![add_n], rng),
            ],
            flops: add_n as f64,
        },
        Case {
            kernel: "softmax",
            inputs: vec![HostTensor::randn(vec![sm.0, sm.1], rng)],
            flops: 5.0 * (sm.0 * sm.1) as f64,
        },
        Case {
            kernel: "mm",
            inputs: vec![
                HostTensor::randn(vec![mm.0, mm.1], rng),
                HostTensor::randn(vec![mm.1, mm.2], rng),
            ],
            flops: 2.0 * (mm.0 * mm.1 * mm.2) as f64,
        },
        Case {
            kernel: "bmm",
            inputs: vec![
                HostTensor::randn(vec![bmm.0, bmm.1, bmm.2], rng),
                HostTensor::randn(vec![bmm.0, bmm.2, bmm.3], rng),
            ],
            flops: 2.0 * (bmm.0 * bmm.1 * bmm.2 * bmm.3) as f64,
        },
    ]
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn main() {
    let secs = std::env::var("NT_BENCH_SECS").ok().and_then(|s| s.parse().ok()).unwrap_or(1u64);
    let threads = std::env::var("NT_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        });
    let min_time = Duration::from_secs(secs);
    println!(
        "native backend bench: serial vs {threads}-thread pooled grid scheduler \
         (>= {secs}s per measurement)"
    );

    // artifact path for comparison, when available (shapes differ — the
    // artifact is compiled for its own shapes, so this is context, not an
    // apples-to-apples series)
    let artifact_registry = Manifest::load(&ninetoothed_repro::artifacts_dir())
        .ok()
        .and_then(|m| Runtime::cpu().ok().map(|r| Registry::new(r, std::sync::Arc::new(m))));
    if artifact_registry.is_none() {
        println!("(no AOT artifacts / PJRT runtime: native-only run)");
    }

    let mut rng = SplitMix64::new(2024);
    let mut table = Table::new(&[
        "kernel", "grid", "serial", "pooled", "speedup", "serial GFLOP/s", "pooled GFLOP/s",
    ]);
    let mut rows = Vec::new();
    for case in cases(&mut rng) {
        let kernel = exec::lookup(case.kernel).expect("native kernel");
        let spec = kernel.specialize(&case.inputs).expect("specialize");
        let serial = GridScheduler::serial();
        let pooled = GridScheduler::pooled(threads);
        let stats_serial = bench_for(1, min_time, || {
            kernel.run(&case.inputs, &serial).expect("serial run");
        });
        let stats_pooled = bench_for(1, min_time, || {
            kernel.run(&case.inputs, &pooled).expect("pooled run");
        });
        let speedup = stats_serial.mean_s / stats_pooled.mean_s;
        table.row(vec![
            case.kernel.to_string(),
            format!("{:?}", spec.grid),
            fmt_duration(stats_serial.mean_s),
            fmt_duration(stats_pooled.mean_s),
            format!("{speedup:.2}x"),
            format!("{:.2}", case.flops / stats_serial.mean_s / 1e9),
            format!("{:.2}", case.flops / stats_pooled.mean_s / 1e9),
        ]);
        rows.push(obj(vec![
            ("kernel", Json::Str(case.kernel.to_string())),
            ("backend", Json::Str("native".to_string())),
            (
                "grid",
                Json::Arr(spec.grid.iter().map(|&g| Json::Num(g as f64)).collect()),
            ),
            ("programs", Json::Num(spec.programs() as f64)),
            ("threads", Json::Num(threads as f64)),
            ("serial_mean_s", Json::Num(stats_serial.mean_s)),
            ("pooled_mean_s", Json::Num(stats_pooled.mean_s)),
            ("speedup", Json::Num(speedup)),
            ("gflops_serial", Json::Num(case.flops / stats_serial.mean_s / 1e9)),
            ("gflops_pooled", Json::Num(case.flops / stats_pooled.mean_s / 1e9)),
        ]));

        // artifact-path comparison at the artifact's own compiled shapes
        if let Some(registry) = &artifact_registry {
            if let Ok(exe) = registry.kernel(case.kernel, "nt") {
                if let Ok(art) = registry.manifest().kernel(case.kernel, "nt") {
                    let mut arng = SplitMix64::new(7);
                    let inputs: Vec<HostTensor> = art
                        .args
                        .iter()
                        .map(|spec| HostTensor::randn(spec.shape.clone(), &mut arng))
                        .collect();
                    let stats = bench_for(1, min_time, || {
                        exe.run(&inputs).expect("artifact run");
                    });
                    rows.push(obj(vec![
                        ("kernel", Json::Str(case.kernel.to_string())),
                        ("backend", Json::Str("artifact".to_string())),
                        ("mean_s", Json::Num(stats.mean_s)),
                    ]));
                    println!(
                        "  {} artifact path ({:?}-shaped): {}",
                        case.kernel,
                        art.args[0].shape,
                        fmt_duration(stats.mean_s)
                    );
                }
            }
        }
    }
    println!("{}", table.render());

    let report = obj(vec![
        ("bench", Json::Str("native_backend".to_string())),
        ("threads", Json::Num(threads as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = "BENCH_native.json";
    match std::fs::write(path, format!("{report}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
    println!(
        "pooled-beats-serial on the large grids above demonstrates the grid scheduler \
         parallelizes (§3.2.1 non-overlap makes cells independent)"
    );
}
