//! `cargo bench --bench native_backend` — native tile-execution backend
//! throughput.
//!
//! Sections:
//!
//! 1. **dot microkernel sweep** — naive i-k-j loop vs the blocked GEMM
//!    on single tiles across sizes (the ISSUE 2 acceptance series: the
//!    512^3 row must show >= 4x GFLOP/s over naive);
//! 2. **kernel sweeps** — mm / bmm / softmax / sdpa GFLOP/s across
//!    sizes, serial vs pooled grid scheduler (grid-vs-intra-tile
//!    parallelism evidence; sdpa is the loop-carried flash-attention
//!    kernel, declared only through `kernel::make`);
//! 3. **plan cache** — cold compile (specialize + lower + probe-verify)
//!    vs warm `PlanCache::prepare` latency: the compile-once/execute-many
//!    evidence, gated so a warm-path regression fails CI;
//! 4. **coalescing** — N same-shape requests executed sequentially vs
//!    stacked into one grid launch (requests/s both ways), plus the
//!    observability-overhead, **flight-recorder** (NDJSON event log on
//!    the admit path) and **autotune** gates (tuned winner vs the
//!    block-size heuristic; warm tuning-table restart must re-measure
//!    nothing);
//! 5. the **artifact path** for context, when AOT artifacts + a PJRT
//!    runtime exist.
//!
//! Emits `BENCH_native.json` with one keyed row per measurement.
//! `tools/bench_check.rs` compares those keys against the committed
//! `BENCH_baseline.json` and fails CI on a > 25% throughput regression.
//!
//! Environment:
//! * `NT_BENCH_SECS`  — min seconds per measurement (float, default 1.0;
//!   0.25 under smoke);
//! * `NT_BENCH_THREADS` — pool width (default = available parallelism);
//! * `NT_BENCH_SMOKE=1` — reduced-size sweep for the CI bench-smoke job.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::time::Duration;

use ninetoothed_repro::benchkit::{bench_for, fmt_duration, Table};
use ninetoothed_repro::coordinator::Coalescer;
use ninetoothed_repro::exec::{self, GridScheduler, PlanCache, Tile, TuneMode, Tuner};
use ninetoothed_repro::obs::{EventLog, MetricsRegistry, Span, SpanKind, Trace, TraceRecorder};
use ninetoothed_repro::json::Json;
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{HostTensor, Manifest, Registry, Runtime};

struct Case {
    key: String,
    kernel: &'static str,
    inputs: Vec<HostTensor>,
    flops: f64,
}

fn mm_case(m: usize, k: usize, n: usize, rng: &mut SplitMix64) -> Case {
    Case {
        key: format!("mm_{m}x{k}x{n}"),
        kernel: "mm",
        inputs: vec![HostTensor::randn(vec![m, k], rng), HostTensor::randn(vec![k, n], rng)],
        flops: 2.0 * (m * k * n) as f64,
    }
}

fn bmm_case(b: usize, m: usize, k: usize, n: usize, rng: &mut SplitMix64) -> Case {
    Case {
        key: format!("bmm_{b}x{m}x{k}x{n}"),
        kernel: "bmm",
        inputs: vec![
            HostTensor::randn(vec![b, m, k], rng),
            HostTensor::randn(vec![b, k, n], rng),
        ],
        flops: 2.0 * (b * m * k * n) as f64,
    }
}

fn softmax_case(r: usize, c: usize, rng: &mut SplitMix64) -> Case {
    Case {
        key: format!("softmax_{r}x{c}"),
        kernel: "softmax",
        inputs: vec![HostTensor::randn(vec![r, c], rng)],
        flops: 5.0 * (r * c) as f64,
    }
}

/// rope is defined *only* through `kernel::make` — its plan row gates the
/// API indirection (warm prepare must stay effectively free per request).
fn rope_case(b: usize, s: usize, h: usize, d: usize, rng: &mut SplitMix64) -> Case {
    Case {
        key: format!("rope_{b}x{s}x{h}x{d}"),
        kernel: "rope",
        inputs: vec![
            HostTensor::randn(vec![b, s, h, d], rng),
            HostTensor::randn(vec![s, d / 2], rng),
            HostTensor::randn(vec![s, d / 2], rng),
        ],
        flops: 6.0 * (b * s * h * d) as f64,
    }
}

/// Flash-style attention — the loop-carried proof kernel.  FLOPs count
/// the two GEMMs (`QK^T` and `PV`): `4 * b * h * s^2 * d`.
fn sdpa_case(b: usize, h: usize, s: usize, d: usize, rng: &mut SplitMix64) -> Case {
    Case {
        key: format!("sdpa_{b}x{h}x{s}x{d}"),
        kernel: "sdpa",
        inputs: (0..3).map(|_| HostTensor::randn(vec![b, h, s, d], rng)).collect(),
        flops: 4.0 * (b * h * s * s * d) as f64,
    }
}

fn kernel_cases(smoke: bool, rng: &mut SplitMix64) -> Vec<Case> {
    let mut cases = vec![
        mm_case(128, 128, 128, rng),
        mm_case(256, 256, 256, rng),
        bmm_case(4, 64, 64, 64, rng),
        softmax_case(256, 2048, rng),
        sdpa_case(1, 4, 256, 64, rng),
    ];
    if !smoke {
        cases.push(mm_case(512, 512, 512, rng));
        cases.push(bmm_case(8, 128, 128, 128, rng));
        cases.push(softmax_case(1024, 4096, rng));
        cases.push(sdpa_case(2, 8, 512, 64, rng));
    }
    cases
}

/// Dot sweep sizes.  384^3 is the smoke gate's collapse detector: B no
/// longer fits per-core L2, so the naive loop turns memory-bound while
/// the packed kernel stays compute-bound — its baseline speedup floor
/// sits well above 1.0, which is what lets `bench_check` actually fail
/// if the blocked path ever regresses to naive throughput.  (Dev-profile
/// runs stop at 256 to keep `cargo test` quick.)
fn dot_sizes(smoke: bool) -> Vec<(usize, usize, usize)> {
    if cfg!(debug_assertions) {
        vec![(128, 128, 128), (256, 256, 256)]
    } else if smoke {
        vec![(128, 128, 128), (256, 256, 256), (384, 384, 384)]
    } else {
        vec![(128, 128, 128), (256, 256, 256), (384, 384, 384), (512, 512, 512)]
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn main() {
    // dev-profile runs (cargo test builds bench targets) always take the
    // reduced sweep; real numbers come from `cargo bench` (release)
    let smoke = std::env::var("NT_BENCH_SMOKE").is_ok_and(|v| v == "1") || cfg!(debug_assertions);
    let secs: f64 = std::env::var("NT_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if cfg!(debug_assertions) {
            0.05
        } else if smoke {
            0.25
        } else {
            1.0
        });
    let threads = std::env::var("NT_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        });
    let min_time = Duration::from_secs_f64(secs);
    println!(
        "native backend bench{}: serial vs {threads}-thread pooled grid scheduler \
         (>= {secs}s per measurement)",
        if smoke { " [smoke]" } else { "" }
    );

    let mut rng = SplitMix64::new(2024);
    let mut rows = Vec::new();

    // -- 1. dot microkernel: naive loop vs blocked GEMM ----------------------
    let mut dot_table =
        Table::new(&["dot (m=k=n)", "naive", "blocked", "naive GF/s", "blocked GF/s", "speedup"]);
    for (m, k, n) in dot_sizes(smoke) {
        let a = Tile::new(vec![m, k], rng.normal_vec(m * k)).expect("tile a");
        let b = Tile::new(vec![k, n], rng.normal_vec(k * n)).expect("tile b");
        let flops = 2.0 * (m * k * n) as f64;
        let naive = bench_for(1, min_time, || {
            a.dot_naive(&b).expect("naive dot");
        });
        let blocked = bench_for(1, min_time, || {
            a.dot_blocked(&b).expect("blocked dot");
        });
        let speedup = naive.mean_s / blocked.mean_s;
        let (gf_naive, gf_blocked) = (flops / naive.mean_s / 1e9, flops / blocked.mean_s / 1e9);
        dot_table.row(vec![
            format!("{m}"),
            fmt_duration(naive.mean_s),
            fmt_duration(blocked.mean_s),
            format!("{gf_naive:.2}"),
            format!("{gf_blocked:.2}"),
            format!("{speedup:.2}x"),
        ]);
        rows.push(obj(vec![
            ("key", Json::Str(format!("dot_{m}x{k}x{n}"))),
            ("kernel", Json::Str("dot".to_string())),
            ("naive_mean_s", Json::Num(naive.mean_s)),
            ("blocked_mean_s", Json::Num(blocked.mean_s)),
            ("naive_gflops", Json::Num(gf_naive)),
            ("gflops", Json::Num(gf_blocked)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    println!("{}", dot_table.render());

    // artifact path for comparison, when available (shapes differ — the
    // artifact is compiled for its own shapes, so this is context, not an
    // apples-to-apples series)
    let artifact_registry = Manifest::load(&ninetoothed_repro::artifacts_dir())
        .ok()
        .and_then(|m| Runtime::cpu().ok().map(|r| Registry::new(r, std::sync::Arc::new(m))));
    if artifact_registry.is_none() {
        println!("(no AOT artifacts / PJRT runtime: native-only run)");
    }

    // -- 2. kernel sweeps: serial vs pooled grid scheduler -------------------
    let mut table = Table::new(&[
        "case", "grid", "serial", "pooled", "speedup", "serial GFLOP/s", "pooled GFLOP/s",
    ]);
    let cases = kernel_cases(smoke, &mut rng);
    let mut benched_kernels: Vec<&'static str> = Vec::new();
    for case in &cases {
        if !benched_kernels.contains(&case.kernel) {
            benched_kernels.push(case.kernel);
        }
    }
    for case in &cases {
        let kernel = exec::lookup(case.kernel).expect("registered kernel");
        let spec = kernel.specialize(&case.inputs).expect("specialize");
        let serial = GridScheduler::serial();
        let pooled = GridScheduler::pooled(threads);
        let stats_serial = bench_for(1, min_time, || {
            kernel.run(&case.inputs, &serial).expect("serial run");
        });
        let stats_pooled = bench_for(1, min_time, || {
            kernel.run(&case.inputs, &pooled).expect("pooled run");
        });
        let speedup = stats_serial.mean_s / stats_pooled.mean_s;
        table.row(vec![
            case.key.clone(),
            format!("{:?}", spec.grid),
            fmt_duration(stats_serial.mean_s),
            fmt_duration(stats_pooled.mean_s),
            format!("{speedup:.2}x"),
            format!("{:.2}", case.flops / stats_serial.mean_s / 1e9),
            format!("{:.2}", case.flops / stats_pooled.mean_s / 1e9),
        ]);
        rows.push(obj(vec![
            ("key", Json::Str(case.key.clone())),
            ("kernel", Json::Str(case.kernel.to_string())),
            ("backend", Json::Str("native".to_string())),
            (
                "grid",
                Json::Arr(spec.grid.iter().map(|&g| Json::Num(g as f64)).collect()),
            ),
            ("programs", Json::Num(spec.programs() as f64)),
            ("threads", Json::Num(threads as f64)),
            ("serial_mean_s", Json::Num(stats_serial.mean_s)),
            ("pooled_mean_s", Json::Num(stats_pooled.mean_s)),
            ("speedup", Json::Num(speedup)),
            ("gflops_serial", Json::Num(case.flops / stats_serial.mean_s / 1e9)),
            ("gflops_pooled", Json::Num(case.flops / stats_pooled.mean_s / 1e9)),
        ]));
    }
    println!("{}", table.render());

    // -- 3. plan cache: cold compile vs warm prepare -------------------------
    let mut plan_table =
        Table::new(&["plan", "cold compile", "warm prepare", "speedup", "warm/s"]);
    for case in [
        mm_case(256, 256, 256, &mut rng),
        softmax_case(256, 2048, &mut rng),
        rope_case(2, 64, 8, 64, &mut rng),
        sdpa_case(1, 4, 256, 64, &mut rng),
    ] {
        let kernel = exec::lookup(case.kernel).expect("registered kernel");
        let shapes: Vec<&[usize]> = case.inputs.iter().map(|t| t.shape.as_slice()).collect();
        let cold = bench_for(1, min_time, || {
            exec::compile(&kernel, &shapes).expect("cold compile");
        });
        let cache = PlanCache::new(64);
        cache.prepare(&kernel, "nt", &shapes).expect("prime the cache");
        let warm = bench_for(1, min_time, || {
            cache.prepare(&kernel, "nt", &shapes).expect("warm prepare");
        });
        let speedup = cold.mean_s / warm.mean_s;
        let warm_per_s = 1.0 / warm.mean_s;
        plan_table.row(vec![
            case.key.clone(),
            fmt_duration(cold.mean_s),
            fmt_duration(warm.mean_s),
            format!("{speedup:.1}x"),
            format!("{warm_per_s:.0}"),
        ]);
        rows.push(obj(vec![
            ("key", Json::Str(format!("plan_{}", case.key))),
            ("kernel", Json::Str(case.kernel.to_string())),
            ("cold_mean_s", Json::Num(cold.mean_s)),
            ("warm_mean_s", Json::Num(warm.mean_s)),
            ("speedup", Json::Num(speedup)),
            ("warm_per_s", Json::Num(warm_per_s)),
        ]));
    }
    println!("{}", plan_table.render());

    // -- 3b. the kernel::make registry: resolve-by-name throughput -----------
    // the API redesign's only per-request indirection is a hash-registry
    // lookup — gate it so it provably stays free on the serving path
    {
        let resolve = bench_for(1, min_time, || {
            assert!(exec::lookup("rope").is_some());
        });
        let resolves_per_s = 1.0 / resolve.mean_s;
        println!("kernel registry resolve (rope): {resolves_per_s:.0}/s");
        rows.push(obj(vec![
            ("key", Json::Str("registry_resolve_rope".to_string())),
            ("kernel", Json::Str("rope".to_string())),
            ("resolves_per_s", Json::Num(resolves_per_s)),
        ]));
    }

    // -- 3c. declaration verifier: full four-pass verification throughput ----
    // registration and `repro lint` both run this; gate it so the static
    // analyses stay a startup-time cost measured in microseconds, never a
    // reason to skip the gate
    {
        let kernel = exec::lookup("mm").expect("mm");
        let checked = bench_for(1, min_time, || {
            assert!(ninetoothed_repro::kernel::verify::verify(&kernel).is_clean());
        });
        let verifications_per_s = 1.0 / checked.mean_s;
        println!("declaration verify (mm, all four analyses): {verifications_per_s:.0}/s");
        rows.push(obj(vec![
            ("key", Json::Str("verify_mm_decl".to_string())),
            ("kernel", Json::Str("mm".to_string())),
            ("verifications_per_s", Json::Num(verifications_per_s)),
        ]));
    }

    // -- 4. coalescing: sequential same-shape requests vs one stacked launch --
    {
        // small per-request rows: a single request's grid cannot fill the
        // pool (the scheduler runs it serially), while the stacked launch
        // fans out — exactly the serving shapes coalescing exists for
        let reqs = 8usize;
        let (r, c) = (16usize, 256usize);
        let kernel = exec::lookup("softmax").expect("softmax");
        let per_request: Vec<Vec<HostTensor>> =
            (0..reqs).map(|_| vec![HostTensor::randn(vec![r, c], &mut rng)]).collect();
        let refs: Vec<Vec<&HostTensor>> =
            per_request.iter().map(|inputs| inputs.iter().collect()).collect();
        let stacked = Coalescer::stack(&refs).expect("stack");
        let pooled = GridScheduler::pooled(threads);
        // compile both shape signatures once; the measurement is pure
        // execute, which is what the serving hot path pays
        let cache = PlanCache::new(8);
        let single_shapes: Vec<&[usize]> =
            per_request[0].iter().map(|t| t.shape.as_slice()).collect();
        let stacked_shapes: Vec<&[usize]> = stacked.iter().map(|t| t.shape.as_slice()).collect();
        let single_plan = cache.prepare(&kernel, "nt", &single_shapes).expect("plan");
        let stacked_plan = cache.prepare(&kernel, "nt", &stacked_shapes).expect("plan");
        let sequential = bench_for(1, min_time, || {
            for inputs in &per_request {
                single_plan.execute(inputs, &pooled).expect("sequential run");
            }
        });
        let coalesced = bench_for(1, min_time, || {
            let outs = stacked_plan.execute(&stacked, &pooled).expect("coalesced run");
            Coalescer::unstack(reqs, outs).expect("unstack");
        });
        let speedup = sequential.mean_s / coalesced.mean_s;
        let (seq_per_s, coal_per_s) =
            (reqs as f64 / sequential.mean_s, reqs as f64 / coalesced.mean_s);
        println!(
            "coalescing ({reqs} x softmax {r}x{c}): sequential {} ({seq_per_s:.0} req/s) vs \
             stacked {} ({coal_per_s:.0} req/s) = {speedup:.2}x",
            fmt_duration(sequential.mean_s),
            fmt_duration(coalesced.mean_s),
        );
        rows.push(obj(vec![
            ("key", Json::Str(format!("coalesce_softmax_{reqs}x{r}x{c}"))),
            ("kernel", Json::Str("softmax".to_string())),
            ("sequential_mean_s", Json::Num(sequential.mean_s)),
            ("coalesced_mean_s", Json::Num(coalesced.mean_s)),
            ("sequential_per_s", Json::Num(seq_per_s)),
            ("coalesced_per_s", Json::Num(coal_per_s)),
            ("speedup", Json::Num(speedup)),
        ]));
    }

    // -- 4b. observability overhead: the obs layer's recording points
    //        (per-kernel registry counters + latency histogram + trace
    //        sampling and recording) added to a coalesced serving-shaped
    //        execution.  Gated: the metrics+tracing-enabled throughput
    //        must stay within 5% of the bare execution (the baseline row
    //        carries its own tolerance).
    {
        let reqs = 8usize;
        let (r, c) = (16usize, 256usize);
        let kernel = exec::lookup("softmax").expect("softmax");
        let per_request: Vec<Vec<HostTensor>> =
            (0..reqs).map(|_| vec![HostTensor::randn(vec![r, c], &mut rng)]).collect();
        let refs: Vec<Vec<&HostTensor>> =
            per_request.iter().map(|inputs| inputs.iter().collect()).collect();
        let stacked = Coalescer::stack(&refs).expect("stack");
        let pooled = GridScheduler::pooled(threads);
        let cache = PlanCache::new(8);
        let stacked_shapes: Vec<&[usize]> = stacked.iter().map(|t| t.shape.as_slice()).collect();
        let plan = cache.prepare(&kernel, "nt", &stacked_shapes).expect("plan");
        let bare = bench_for(1, min_time, || {
            let outs = plan.execute(&stacked, &pooled).expect("bare run");
            Coalescer::unstack(reqs, outs).expect("unstack");
        });
        let registry = MetricsRegistry::new();
        let traces = TraceRecorder::new(1, 256);
        let shape = format!("{r}x{c}");
        let observed = bench_for(1, min_time, || {
            let outs = plan.execute(&stacked, &pooled).expect("observed run");
            Coalescer::unstack(reqs, outs).expect("unstack");
            // the per-request recording the coordinator does on this path
            for _ in 0..reqs {
                let m = registry.handle("softmax", &shape);
                m.submitted.fetch_add(1, Ordering::Relaxed);
                m.completed.fetch_add(1, Ordering::Relaxed);
                m.coalesced.fetch_add(1, Ordering::Relaxed);
                m.observe_latency_us(64);
                if traces.should_sample() {
                    traces.record(Trace {
                        kernel: "softmax".to_string(),
                        shapes: shape.clone(),
                        batch_size: reqs,
                        coalesced: true,
                        plan_hit: Some(true),
                        total_us: 64,
                        trace_id: None,
                        client_id: None,
                        spans: vec![
                            Span { kind: SpanKind::Queued, start_us: 0, end_us: 8 },
                            Span { kind: SpanKind::Execute, start_us: 8, end_us: 60 },
                            Span { kind: SpanKind::Reply, start_us: 60, end_us: 64 },
                        ],
                    });
                }
            }
        });
        let rel = bare.mean_s / observed.mean_s;
        let coal_per_s = reqs as f64 / observed.mean_s;
        println!(
            "obs overhead ({reqs} x softmax {r}x{c} coalesced): bare {} vs observed {} \
             ({coal_per_s:.0} req/s, {:.1}% overhead)",
            fmt_duration(bare.mean_s),
            fmt_duration(observed.mean_s),
            (1.0 / rel - 1.0) * 100.0,
        );
        rows.push(obj(vec![
            ("key", Json::Str(format!("obs_overhead_softmax_{reqs}x{r}x{c}"))),
            ("kernel", Json::Str("softmax".to_string())),
            ("bare_mean_s", Json::Num(bare.mean_s)),
            ("observed_mean_s", Json::Num(observed.mean_s)),
            ("coalesced_per_s", Json::Num(coal_per_s)),
            ("obs_rel_throughput", Json::Num(rel)),
        ]));
    }

    // -- 4b2. flight-recorder overhead: the same serving-shaped coalesced
    //         execution with an admit event written per request through an
    //         enabled NDJSON EventLog (one locked write_all per line).
    //         Gated: `eventlog_rel_throughput` must stay >= 0.95 of the
    //         bare execution (baseline row tolerance).
    {
        let reqs = 8usize;
        let (r, c) = (16usize, 256usize);
        let kernel = exec::lookup("softmax").expect("softmax");
        let per_request: Vec<Vec<HostTensor>> =
            (0..reqs).map(|_| vec![HostTensor::randn(vec![r, c], &mut rng)]).collect();
        let refs: Vec<Vec<&HostTensor>> =
            per_request.iter().map(|inputs| inputs.iter().collect()).collect();
        let stacked = Coalescer::stack(&refs).expect("stack");
        let pooled = GridScheduler::pooled(threads);
        let cache = PlanCache::new(8);
        let stacked_shapes: Vec<&[usize]> = stacked.iter().map(|t| t.shape.as_slice()).collect();
        let plan = cache.prepare(&kernel, "nt", &stacked_shapes).expect("plan");
        let bare = bench_for(1, min_time, || {
            let outs = plan.execute(&stacked, &pooled).expect("bare run");
            Coalescer::unstack(reqs, outs).expect("unstack");
        });
        let log_path =
            std::env::temp_dir().join(format!("nt_bench_events_{}.ndjson", std::process::id()));
        let _ = std::fs::remove_file(&log_path);
        let log = EventLog::to_file(log_path.clone(), 64 << 20, None).expect("event log");
        let shape = format!("{r}x{c}");
        let logged = bench_for(1, min_time, || {
            let outs = plan.execute(&stacked, &pooled).expect("logged run");
            Coalescer::unstack(reqs, outs).expect("unstack");
            // the admission event the coordinator emits per enqueued request
            for _ in 0..reqs {
                log.admit("softmax", &shape, Some("bench"));
            }
        });
        let rel = bare.mean_s / logged.mean_s;
        let coal_per_s = reqs as f64 / logged.mean_s;
        println!(
            "event-log overhead ({reqs} x softmax {r}x{c} coalesced): bare {} vs logged {} \
             ({coal_per_s:.0} req/s, {:.1}% overhead)",
            fmt_duration(bare.mean_s),
            fmt_duration(logged.mean_s),
            (1.0 / rel - 1.0) * 100.0,
        );
        rows.push(obj(vec![
            ("key", Json::Str(format!("obs_eventlog_softmax_{reqs}x{r}x{c}"))),
            ("kernel", Json::Str("softmax".to_string())),
            ("bare_mean_s", Json::Num(bare.mean_s)),
            ("logged_mean_s", Json::Num(logged.mean_s)),
            ("coalesced_per_s", Json::Num(coal_per_s)),
            ("eventlog_rel_throughput", Json::Num(rel)),
        ]));
        let _ = std::fs::remove_file(&log_path);
        let _ = std::fs::remove_file(ninetoothed_repro::obs::events::rotated_path(&log_path));
    }

    // -- 4c. autotune: elected winner vs the block-size heuristic, plus the
    //        warm table restart.  `tuned_rel_throughput` is gated >= 1.0
    //        with a per-row 5% tolerance in the baseline: the tuner may
    //        tie the heuristic (winner index 0 pins the ratio to exactly
    //        1.0 — identical plans, nothing to re-measure) but must never
    //        lose to it.  `restart_zero_measurements` gates the warm
    //        start: a fresh tuner restoring the just-written table must
    //        install every winner without a single timed execution.
    {
        let mut tune_cases = vec![sdpa_case(1, 4, 256, 64, &mut rng)];
        if !smoke {
            tune_cases.push(mm_case(512, 512, 512, &mut rng));
        }
        let table_path =
            std::env::temp_dir().join(format!("nt_bench_tune_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&table_path);
        let plans = std::sync::Arc::new(PlanCache::new(64));
        let tuner = Tuner::new(TuneMode::FirstUse, Some(table_path.clone()), plans);
        let pooled = GridScheduler::pooled(threads);
        for case in &tune_cases {
            let kernel = exec::lookup(case.kernel).expect("registered kernel");
            let shapes: Vec<&[usize]> = case.inputs.iter().map(|t| t.shape.as_slice()).collect();
            let candidates = kernel.meta_candidates(&shapes).expect("candidate space");
            let outcome = tuner
                .tune_with_candidates(&kernel, "nt", &case.inputs, &candidates, &pooled)
                .expect("tune");
            let rel = if outcome.winner_index == 0 {
                1.0
            } else {
                let heuristic = exec::compile(&kernel, &shapes).expect("heuristic compile");
                let tuned = exec::compile_with_meta(&kernel, &shapes, &outcome.winner)
                    .expect("tuned compile");
                let base = bench_for(1, min_time, || {
                    heuristic.execute(&case.inputs, &pooled).expect("heuristic run");
                });
                let best = bench_for(1, min_time, || {
                    tuned.execute(&case.inputs, &pooled).expect("tuned run");
                });
                base.mean_s / best.mean_s
            };
            let winner: Vec<String> =
                outcome.winner.iter().map(|(k, v)| format!("{k}={v}")).collect();
            println!(
                "autotune {}: {} candidates, winner #{} [{}], rel throughput {rel:.2}x \
                 (search {} over {} measurement(s), {} skipped)",
                case.key,
                outcome.candidates,
                outcome.winner_index,
                winner.join(" "),
                fmt_duration(outcome.tune_us as f64 / 1e6),
                outcome.measurements,
                outcome.skipped,
            );
            rows.push(obj(vec![
                ("key", Json::Str(format!("tuned_{}", case.key))),
                ("kernel", Json::Str(case.kernel.to_string())),
                ("candidates", Json::Num(outcome.candidates as f64)),
                ("winner_index", Json::Num(outcome.winner_index as f64)),
                ("tune_us", Json::Num(outcome.tune_us as f64)),
                ("tuned_rel_throughput", Json::Num(rel)),
            ]));
        }
        // warm restart: a fresh tuner against the table the searches above
        // just wrote must restore every winner with zero measurements
        let plans2 = std::sync::Arc::new(PlanCache::new(64));
        let tuner2 = Tuner::new(TuneMode::FirstUse, Some(table_path.clone()), plans2);
        let restored = tuner2.restore();
        let warm = tuner2.measurements() == 0 && restored == tune_cases.len();
        let zero = if warm { 1.0 } else { 0.0 };
        println!(
            "tune table restart: restored {restored}/{} winner(s) with {} measurement(s) -> {}",
            tune_cases.len(),
            tuner2.measurements(),
            if zero == 1.0 { "ok" } else { "FAIL" },
        );
        rows.push(obj(vec![
            ("key", Json::Str("tune_table_restart".to_string())),
            ("kernel", Json::Str("tuner".to_string())),
            ("restored", Json::Num(restored as f64)),
            ("restart_zero_measurements", Json::Num(zero)),
        ]));
        let _ = std::fs::remove_file(&table_path);
    }

    // -- 5. artifact-path comparison, once per kernel, at the artifact's own
    //       compiled shapes
    if let Some(registry) = &artifact_registry {
        for kernel in benched_kernels {
            if let Ok(exe) = registry.kernel(kernel, "nt") {
                if let Ok(art) = registry.manifest().kernel(kernel, "nt") {
                    let mut arng = SplitMix64::new(7);
                    let inputs: Vec<HostTensor> = art
                        .args
                        .iter()
                        .map(|spec| HostTensor::randn(spec.shape.clone(), &mut arng))
                        .collect();
                    let stats = bench_for(1, min_time, || {
                        exe.run(&inputs).expect("artifact run");
                    });
                    rows.push(obj(vec![
                        ("key", Json::Str(format!("{kernel}_artifact"))),
                        ("kernel", Json::Str(kernel.to_string())),
                        ("backend", Json::Str("artifact".to_string())),
                        ("mean_s", Json::Num(stats.mean_s)),
                    ]));
                    println!(
                        "  {} artifact path ({:?}-shaped): {}",
                        kernel,
                        art.args[0].shape,
                        fmt_duration(stats.mean_s)
                    );
                }
            }
        }
    }

    let report = obj(vec![
        ("bench", Json::Str("native_backend".to_string())),
        ("smoke", Json::Bool(smoke)),
        ("threads", Json::Num(threads as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = "BENCH_native.json";
    match std::fs::write(path, format!("{report}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
    println!(
        "gate: `cargo run --release --bin bench_check` compares the keyed rows above \
         against BENCH_baseline.json (>25% throughput regression fails; --update rebaselines)"
    );
}
