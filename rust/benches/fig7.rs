//! `cargo bench --bench fig7` — regenerates paper Fig 7 (end-to-end model
//! inference throughput at several output lengths, three kernel backends).

use std::sync::Arc;

use ninetoothed_repro::harness::fig7;
use ninetoothed_repro::runtime::{Manifest, Registry, Runtime};

fn main() {
    let manifest = match Manifest::load(&ninetoothed_repro::artifacts_dir()) {
        Ok(m) => Arc::new(m),
        Err(e) => {
            println!("skipping fig7 bench (requires `make artifacts`): {e:#}");
            return;
        }
    };
    let runtime = match Runtime::cpu() {
        Ok(r) => r,
        Err(e) => {
            println!("skipping fig7 bench (requires a PJRT runtime): {e:#}");
            return;
        }
    };
    let registry = Arc::new(Registry::new(runtime, manifest));
    let iters = std::env::var("NT_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3usize);
    let model = registry.manifest().model.as_ref().expect("model");
    println!(
        "Fig 7 bench: tiny-Llama d={} L={}, batch {}, prompt {}, {iters} measured iterations",
        model.d_model, model.n_layers, model.batch, model.prompt
    );
    let results = fig7::run_all(&registry, iters).expect("fig7");
    println!("{}", fig7::report(&results));
}
