//! `cargo bench --bench table2` — regenerates paper Table 2 (code metrics).
//! Not a timing benchmark: the "measurement" is the metric suite itself,
//! plus a micro-benchmark of the Rust analyzer's throughput.

use std::time::Duration;

use ninetoothed_repro::benchkit::{bench_for, fmt_duration};
use ninetoothed_repro::cli::Args;
use ninetoothed_repro::codemetrics;
use ninetoothed_repro::harness::table2;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    if let Err(e) = table2::run(&args) {
        // the AST-exact rows live in the AOT manifest; without it only the
        // Rust-lexer micro-bench below can run
        println!("skipping table 2 rows (requires `make artifacts`): {e:#}");
    }

    // analyzer throughput (keeps this an honest `cargo bench` target)
    let Ok(source) = std::fs::read_to_string(
        ninetoothed_repro::harness::repo_root().join("python/compile/kernels/baseline/sdpa.py"),
    ) else {
        println!("skipping analyzer micro-bench: sdpa baseline source not found");
        return;
    };
    let stats = bench_for(3, Duration::from_millis(500), || {
        let region = codemetrics::measured_region(&source);
        let metrics = codemetrics::analyze(&region);
        assert!(metrics.loc > 0);
    });
    println!(
        "analyzer micro-bench: {} per file (sdpa baseline, {} runs)",
        fmt_duration(stats.mean_s),
        stats.n
    );
}
